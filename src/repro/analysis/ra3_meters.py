"""RA3 — meter drift (stats surfaces vs ``docs/meters.md``).

``docs/meters.md`` promises: "If a key is not listed here, it is not
part of the surface."  This rule makes the promise mechanical, in both
directions, for the four meter surfaces:

* ``RunResult.stats`` — the union of ``ReactorStats.as_dict()``,
  ``_ProcessDriver.stats_extra()``, ``ServerCore.memory_stats()`` and
  the ``stats["..."]`` assignments in ``ServerCore.run_stats()``;
* ``EpochStats.as_dict()``;
* ``RunResult``'s own fields and properties;
* the ``observe()`` snapshot dict.

Keys come straight out of the AST (dict literals, ``dict(k=...)``
keywords, subscript assignments); the docs side comes from the tables
under the section headings named below.
"""
from __future__ import annotations

import ast

from repro.analysis import docsmd, engine
from repro.analysis.engine import Finding

TITLE = "meter drift (stats/EpochStats/observe vs docs/meters.md)"

DOCS = "docs/meters.md"
SERVER = "src/repro/core/server.py"
REACTOR = "src/repro/core/reactor.py"
RUNTIME = "src/repro/core/runtime.py"

#: docs/meters.md section-heading substrings -> which surface they feed
STATS_SECTIONS = ("Reactor counters", "Driver wire/codec meters",
                  "Memory-subsystem meters",
                  "Scheduler / observability counters")
EPOCH_SECTION = "EpochStats"
RUNRESULT_SECTION = "RunResult` (one-shot"
OBSERVE_SECTION = "observe()"


def _subscript_assign_keys(fn: ast.AST, target: str
                           ) -> list[tuple[str, int]]:
    """Keys of ``target["k"] = ...`` assignments inside ``fn``."""
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        for t in node.targets:
            if isinstance(t, ast.Subscript) \
                    and isinstance(t.value, ast.Name) \
                    and t.value.id == target \
                    and isinstance(t.slice, ast.Constant) \
                    and isinstance(t.slice.value, str):
                out.append((t.slice.value, node.lineno))
    return out


def _stats_code_keys(project: engine.Project, findings: list[Finding]
                     ) -> dict[str, tuple[str, int]]:
    """stats key -> (path, line) across the four contributing layers."""
    keys: dict[str, tuple[str, int]] = {}

    def add(pairs, path):
        for k, line in pairs:
            keys.setdefault(k, (path, line))

    sf = project.source(REACTOR)
    if sf is None:
        findings.append(project.missing("RA3", REACTOR))
    else:
        cls = engine.top_level_class(sf.tree, "ReactorStats")
        m = cls and engine.class_method(cls, "as_dict")
        if m is None:
            findings.append(Finding(
                "RA3", REACTOR, 0, "ReactorStats.as_dict not found",
                key="RA3:no-reactor-stats"))
        else:
            add(engine.returned_dict_keys(m), REACTOR)
    sf = project.source(RUNTIME)
    if sf is None:
        findings.append(project.missing("RA3", RUNTIME))
    else:
        cls = engine.top_level_class(sf.tree, "_ProcessDriver")
        m = cls and engine.class_method(cls, "stats_extra")
        if m is None:
            findings.append(Finding(
                "RA3", RUNTIME, 0,
                "_ProcessDriver.stats_extra not found",
                key="RA3:no-stats-extra"))
        else:
            add(engine.returned_dict_keys(m), RUNTIME)
    sf = project.source(SERVER)
    if sf is None:
        findings.append(project.missing("RA3", SERVER))
        return keys
    cls = engine.top_level_class(sf.tree, "ServerCore")
    for name, how in (("memory_stats", "dict"), ("run_stats", "sub")):
        m = cls and engine.class_method(cls, name)
        if m is None:
            findings.append(Finding(
                "RA3", SERVER, 0, f"ServerCore.{name} not found",
                key=f"RA3:no-{name}"))
        elif how == "dict":
            add(engine.returned_dict_keys(m), SERVER)
        else:
            add(_subscript_assign_keys(m, "stats"), SERVER)
    return keys


def _doc_keys(doc: str, sections: tuple[str, ...] | str,
              findings: list[Finding]) -> dict[str, int] | None:
    if isinstance(sections, str):
        sections = (sections,)
    keys: dict[str, int] = {}
    for sec in sections:
        rows = docsmd.section_rows(doc, sec)
        if rows is None:
            findings.append(Finding(
                "RA3", DOCS, 0,
                f"no '## …{sec}…' section found in {DOCS}",
                key=f"RA3:docs-no-section:{sec}"))
            return None
        for r in rows:
            keys.setdefault(r.key, r.line)
    return keys


def _diff(surface: str, code: dict[str, tuple[str, int]],
          doc: dict[str, int], findings: list[Finding]) -> None:
    for k in sorted(set(code) - set(doc)):
        path, line = code[k]
        findings.append(Finding(
            "RA3", path, line,
            f"{surface} key {k!r} is not documented in {DOCS}",
            key=f"RA3:{surface}:undocumented:{k}"))
    for k in sorted(set(doc) - set(code)):
        findings.append(Finding(
            "RA3", DOCS, doc[k],
            f"{DOCS} documents {surface} key {k!r} the code never "
            f"produces",
            key=f"RA3:{surface}:stale-doc:{k}"))


def check(project: engine.Project) -> list[Finding]:
    findings: list[Finding] = []
    doc = project.text(DOCS)
    if doc is None:
        return [project.missing("RA3", DOCS)]
    # RunResult.stats ---------------------------------------------------
    code = _stats_code_keys(project, findings)
    dock = _doc_keys(doc, STATS_SECTIONS, findings)
    if dock is not None:
        _diff("stats", code, dock, findings)
    sf = project.source(SERVER)
    if sf is None:
        return findings
    # EpochStats.as_dict ------------------------------------------------
    cls = engine.top_level_class(sf.tree, "EpochStats")
    m = cls and engine.class_method(cls, "as_dict")
    if m is None:
        findings.append(Finding(
            "RA3", SERVER, 0, "EpochStats.as_dict not found",
            key="RA3:no-epoch-stats"))
    else:
        dock = _doc_keys(doc, EPOCH_SECTION, findings)
        if dock is not None:
            _diff("epoch",
                  {k: (SERVER, ln)
                   for k, ln in engine.returned_dict_keys(m)},
                  dock, findings)
    # RunResult fields + properties ------------------------------------
    cls = engine.top_level_class(sf.tree, "RunResult")
    if cls is None:
        findings.append(Finding(
            "RA3", SERVER, 0, "RunResult not found",
            key="RA3:no-runresult"))
    else:
        fields: dict[str, int] = {}
        for node in cls.body:
            if isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                fields[node.target.id] = node.lineno
            elif isinstance(node, ast.FunctionDef) and any(
                    isinstance(d, ast.Name) and d.id == "property"
                    for d in node.decorator_list):
                fields[node.name] = node.lineno
        dock = _doc_keys(doc, RUNRESULT_SECTION, findings)
        if dock is not None:
            _diff("runresult",
                  {k: (SERVER, ln) for k, ln in fields.items()},
                  dock, findings)
    # observe() ---------------------------------------------------------
    cls = engine.top_level_class(sf.tree, "ServerCore")
    m = cls and engine.class_method(cls, "observe")
    if m is None:
        findings.append(Finding(
            "RA3", SERVER, 0, "ServerCore.observe not found",
            key="RA3:no-observe"))
    else:
        dock = _doc_keys(doc, OBSERVE_SECTION, findings)
        if dock is not None:
            _diff("observe",
                  {k: (SERVER, ln)
                   for k, ln in engine.returned_dict_keys(m)},
                  dock, findings)
    return findings
