"""RA6 — protocol spec well-formedness (``analysis/protocol.py`` vs
``core/events.py``).

The executable spec is only an oracle if it covers the actual
vocabulary and is internally coherent.  This rule pins, by parsing both
files' literals with :mod:`ast` (never importing them):

* ``protocol.EVENT_FIELDS`` mirrors ``events.EVENT_TYPES`` type-for-type
  and field-for-field, both directions — a new event type must be given
  protocol semantics the moment it exists;
* the TASK/WORKER/EPOCH/STATELESS partition covers every type exactly
  once;
* every transition edge references declared states and partition-correct
  events, and every task/worker event is consumed by at least one edge;
* every state is reachable from the initial state over declared edges.
"""
from __future__ import annotations

import ast

from repro.analysis import engine
from repro.analysis.engine import Finding
from repro.analysis.ra2_events import _event_types

TITLE = "protocol spec coverage (protocol.py vs events.py vocabulary)"

PROTOCOL = "src/repro/analysis/protocol.py"
EVENTS = "src/repro/core/events.py"

_PARTITIONS = ("TASK_EVENTS", "WORKER_EVENTS", "EPOCH_EVENTS",
               "STATELESS_EVENTS")


def _assign_value(sf: engine.SourceFile, name: str):
    """``(ast value node, lineno)`` of a top-level ``name = literal``."""
    for node in sf.tree.body:
        target = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target = node.targets[0]
        elif isinstance(node, ast.AnnAssign):
            target = node.target
        if isinstance(target, ast.Name) and target.id == name:
            return node.value, node.lineno
    return None, 0


def _str_items(value) -> list[tuple[str, int]]:
    """Strings of a tuple/list literal, with linenos."""
    return [(e.value, e.lineno) for e in getattr(value, "elts", [])
            if isinstance(e, ast.Constant) and isinstance(e.value, str)]


def _fields_dict(value) -> dict[str, tuple[tuple[str, ...], int]]:
    """``{"type": ("f1", "f2")}`` literal -> type -> (fields, lineno)."""
    out: dict[str, tuple[tuple[str, ...], int]] = {}
    if not isinstance(value, ast.Dict):
        return out
    for k, v in zip(value.keys, value.values):
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            fields = tuple(e.value for e in getattr(v, "elts", [])
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, str))
            out[k.value] = (fields, k.lineno)
    return out


def _edges(value) -> dict[tuple[str, str], tuple[str, int]]:
    """``{(state, event): next_state}`` literal -> edge -> (target,
    lineno)."""
    out: dict[tuple[str, str], tuple[str, int]] = {}
    if not isinstance(value, ast.Dict):
        return out
    for k, v in zip(value.keys, value.values):
        if isinstance(k, ast.Tuple) and len(k.elts) == 2 \
                and all(isinstance(e, ast.Constant)
                        and isinstance(e.value, str) for e in k.elts) \
                and isinstance(v, ast.Constant) \
                and isinstance(v.value, str):
            out[(k.elts[0].value, k.elts[1].value)] = (v.value, k.lineno)
    return out


def _check_machine(findings, rel, name, edges, states, events,
                   decl_line) -> None:
    """Edge well-formedness + event coverage + state reachability for
    one machine (``name`` in {"task", "worker"})."""
    state_set = {s for s, _ in states}
    event_set = {e for e, _ in events}
    used_events: set[str] = set()
    for (frm, evt), (to, lineno) in sorted(edges.items()):
        used_events.add(evt)
        for s, what in ((frm, "source"), (to, "target")):
            if s not in state_set:
                findings.append(Finding(
                    "RA6", rel, lineno,
                    f"{name} edge ({frm!r}, {evt!r}) -> {to!r} uses "
                    f"undeclared {what} state {s!r}",
                    key=f"RA6:bad-edge:{name}:{frm}:{evt}"))
        if evt not in event_set:
            findings.append(Finding(
                "RA6", rel, lineno,
                f"{name} edge ({frm!r}, {evt!r}) consumes an event "
                f"outside {name.upper()}_EVENTS",
                key=f"RA6:bad-edge:{name}:{frm}:{evt}"))
    for evt, lineno in sorted(events):
        if evt not in used_events:
            findings.append(Finding(
                "RA6", rel, lineno,
                f"{name} event {evt!r} is consumed by no transition "
                f"edge — the machine cannot accept it",
                key=f"RA6:unused-event:{name}:{evt}"))
    if not states:
        return
    init = states[0][0]
    seen = {init}
    frontier = [init]
    while frontier:
        s = frontier.pop()
        for (frm, _evt), (to, _ln) in edges.items():
            if frm == s and to not in seen:
                seen.add(to)
                frontier.append(to)
    for s, lineno in states:
        if s not in seen:
            findings.append(Finding(
                "RA6", rel, lineno,
                f"{name} state {s!r} is unreachable from {init!r} over "
                f"the declared edges",
                key=f"RA6:unreachable-state:{name}:{s}"))


def check(project: engine.Project) -> list[Finding]:
    sf_p = project.source(PROTOCOL)
    if sf_p is None:
        return [project.missing("RA6", PROTOCOL)]
    sf_ev = project.source(EVENTS)
    if sf_ev is None:
        return [project.missing("RA6", EVENTS)]
    findings: list[Finding] = []

    spec_val, spec_line = _assign_value(sf_p, "EVENT_FIELDS")
    spec = _fields_dict(spec_val)
    if not spec:
        return [Finding("RA6", PROTOCOL, spec_line,
                        "EVENT_FIELDS dict literal not found",
                        key="RA6:no-event-fields")]
    vocab, vocab_line = _event_types(sf_ev)

    # -- vocabulary mirror, both directions ---------------------------
    for type_ in sorted(set(vocab) - set(spec)):
        findings.append(Finding(
            "RA6", PROTOCOL, spec_line,
            f"event type {type_!r} (events.py:{vocab[type_][1]}) has no "
            f"protocol semantics in EVENT_FIELDS",
            key=f"RA6:vocab-missing:{type_}"))
    for type_ in sorted(set(spec) - set(vocab)):
        findings.append(Finding(
            "RA6", PROTOCOL, spec[type_][1],
            f"EVENT_FIELDS declares {type_!r} which EVENT_TYPES no "
            f"longer has",
            key=f"RA6:vocab-stale:{type_}"))
    for type_ in sorted(set(spec) & set(vocab)):
        if spec[type_][0] != vocab[type_][0]:
            findings.append(Finding(
                "RA6", PROTOCOL, spec[type_][1],
                f"{type_!r} fields drifted: protocol says "
                f"{list(spec[type_][0])}, EVENT_TYPES says "
                f"{list(vocab[type_][0])}",
                key=f"RA6:vocab-fields:{type_}"))

    # -- partition: every spec type in exactly one set ----------------
    membership: dict[str, list[str]] = {t: [] for t in spec}
    parts: dict[str, list[tuple[str, int]]] = {}
    for pname in _PARTITIONS:
        val, _ = _assign_value(sf_p, pname)
        parts[pname] = _str_items(val)
        for t, lineno in parts[pname]:
            if t in membership:
                membership[t].append(pname)
            else:
                findings.append(Finding(
                    "RA6", PROTOCOL, lineno,
                    f"{pname} lists {t!r} which is not in EVENT_FIELDS",
                    key=f"RA6:partition:{t}"))
    for t in sorted(membership):
        n = len(membership[t])
        if n != 1:
            findings.append(Finding(
                "RA6", PROTOCOL, spec[t][1],
                f"event type {t!r} is in {n} partition sets "
                f"({membership[t] or 'none'}); must be in exactly one",
                key=f"RA6:partition:{t}"))

    # -- state machines -----------------------------------------------
    task_states = _str_items(_assign_value(sf_p, "TASK_STATES")[0])
    worker_states = _str_items(_assign_value(sf_p, "WORKER_STATES")[0])
    task_edges = _edges(_assign_value(sf_p, "TASK_TRANSITIONS")[0])
    worker_edges = _edges(_assign_value(sf_p, "WORKER_TRANSITIONS")[0])
    _check_machine(findings, PROTOCOL, "task", task_edges, task_states,
                   parts.get("TASK_EVENTS", []), spec_line)
    _check_machine(findings, PROTOCOL, "worker", worker_edges,
                   worker_states, parts.get("WORKER_EVENTS", []),
                   spec_line)
    return findings
