"""RA2 — event-schema conformance (``core/events.py`` vs publish sites
vs ``docs/events.md``).

``EVENT_TYPES`` is the single vocabulary; this rule pins it from three
sides:

* every ``publish("type", field=...)`` call site uses a declared type
  with exactly the declared fields;
* every declared type is published somewhere (or allowlisted) — dead
  vocabulary is drift waiting to be misread;
* the ``docs/events.md`` tables agree with the vocabulary, type for
  type and field for field.

A publish whose type argument is not a string literal must carry a
``# ra: event-types a,b`` pragma naming the types that flow through
it; each named type is then field-checked as usual.  Fields beyond the
declared set are findings — additive optional fields are allowed by
the schema's versioning policy, but must be allowlisted here (with the
doc pointer as justification) so they stay a deliberate act.
"""
from __future__ import annotations

import ast

from repro.analysis import docsmd, engine
from repro.analysis.engine import Finding

TITLE = "event-schema conformance (events.py / publish sites / docs)"

EVENTS = "src/repro/core/events.py"
DOCS = "docs/events.md"
DOCS_SECTION = "Event types"
#: every module that may publish; a site elsewhere simply isn't seen,
#: so new publishers must be added here (docs/analysis.md says so)
SCAN = (
    "src/repro/core/events.py",
    "src/repro/core/server.py",
    "src/repro/core/simulator.py",
    "src/repro/core/store.py",
    "src/repro/core/runtime.py",
    "src/repro/serve/engine.py",
    "src/repro/train/trainer.py",
)


def _event_types(sf: engine.SourceFile
                 ) -> tuple[dict[str, tuple[tuple[str, ...], int]], int]:
    """Parse the ``EVENT_TYPES`` literal: type -> (fields, lineno)."""
    for node in sf.tree.body:
        target = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target = node.targets[0]
        elif isinstance(node, ast.AnnAssign):
            target = node.target
        if target is not None and isinstance(target, ast.Name) \
                and target.id == "EVENT_TYPES" \
                and isinstance(node.value, ast.Dict):
            out: dict[str, tuple[tuple[str, ...], int]] = {}
            for k, v in zip(node.value.keys, node.value.values):
                if not (isinstance(k, ast.Constant)
                        and isinstance(k.value, str)):
                    continue
                fields = tuple(
                    e.value for e in getattr(v, "elts", [])
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str))
                out[k.value] = (fields, k.lineno)
            return out, node.lineno
    return {}, 0


def _publish_calls(sf: engine.SourceFile
                   ) -> list[tuple[ast.Call, str | None]]:
    """``(call, literal_type_or_None)`` for every ``*.publish(...)``
    call except the ``EventBus.publish`` definition's own body."""
    out = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "publish" and node.args:
            a0 = node.args[0]
            lit = a0.value if (isinstance(a0, ast.Constant)
                              and isinstance(a0.value, str)) else None
            out.append((node, lit))
    return out


def _check_fields(sf, call, type_, declared, findings) -> None:
    fields, lineno = declared
    kwargs = {kw.arg for kw in call.keywords if kw.arg is not None}
    if any(kw.arg is None for kw in call.keywords):
        findings.append(Finding(
            "RA2", sf.rel, call.lineno,
            f"publish({type_!r}, **...) spreads unknown fields — "
            f"spell them out so the schema is checkable",
            key=f"RA2:splat:{type_}"))
        return
    for f in sorted(set(fields) - kwargs):
        findings.append(Finding(
            "RA2", sf.rel, call.lineno,
            f"publish({type_!r}) omits declared field {f!r}",
            key=f"RA2:missing-field:{type_}:{f}"))
    for f in sorted(kwargs - set(fields)):
        findings.append(Finding(
            "RA2", sf.rel, call.lineno,
            f"publish({type_!r}) passes field {f!r} not declared in "
            f"EVENT_TYPES (additive optional fields need an allowlist "
            f"entry citing docs/events.md)",
            key=f"RA2:extra-field:{type_}:{f}"))


def check(project: engine.Project) -> list[Finding]:
    sf_ev = project.source(EVENTS)
    if sf_ev is None:
        return [project.missing("RA2", EVENTS)]
    findings: list[Finding] = []
    types, decl_line = _event_types(sf_ev)
    if not types:
        return [Finding("RA2", EVENTS, 0,
                        "EVENT_TYPES dict literal not found",
                        key="RA2:no-event-types")]
    published: set[str] = set()
    for rel in SCAN:
        sf = project.source(rel)
        if sf is None:
            findings.append(project.missing("RA2", rel))
            continue
        for call, lit in _publish_calls(sf):
            if lit is None:
                pragma = sf.pragma_for(call, "event-types")
                if pragma is None:
                    findings.append(Finding(
                        "RA2", sf.rel, call.lineno,
                        "publish() with a non-literal event type — "
                        "annotate the site with '# ra: event-types "
                        "a,b' naming the types that flow through it",
                        key=f"RA2:dynamic-publish:{sf.rel}"))
                    continue
                names = [t.strip() for t in pragma.split(",")
                         if t.strip()]
            else:
                names = [lit]
            for type_ in names:
                if type_ not in types:
                    findings.append(Finding(
                        "RA2", sf.rel, call.lineno,
                        f"publish({type_!r}): type not declared in "
                        f"EVENT_TYPES",
                        key=f"RA2:unknown-type:{type_}"))
                    continue
                published.add(type_)
                _check_fields(sf, call, type_, types[type_], findings)
    for type_ in sorted(set(types) - published):
        findings.append(Finding(
            "RA2", EVENTS, types[type_][1],
            f"EVENT_TYPES declares {type_!r} but no scanned module "
            f"publishes it",
            key=f"RA2:unpublished:{type_}"))
    # --- docs/events.md agreement ------------------------------------
    doc = project.text(DOCS)
    if doc is None:
        findings.append(project.missing("RA2", DOCS))
        return findings
    rows = docsmd.section_rows(doc, DOCS_SECTION)
    if rows is None:
        findings.append(Finding(
            "RA2", DOCS, 0,
            f"no '## {DOCS_SECTION}' section found",
            key="RA2:docs-no-section"))
        return findings
    doc_types = {r.key: r for r in rows}
    for type_ in sorted(set(types) - set(doc_types)):
        findings.append(Finding(
            "RA2", EVENTS, types[type_][1],
            f"event type {type_!r} is not documented in {DOCS}",
            key=f"RA2:undocumented:{type_}"))
    for type_, row in sorted(doc_types.items()):
        if type_ not in types:
            findings.append(Finding(
                "RA2", DOCS, row.line,
                f"{DOCS} documents unknown event type {type_!r}",
                key=f"RA2:docs-stale:{type_}"))
            continue
        doc_fields = row.ticked_fields(1)
        declared = list(types[type_][0])
        if doc_fields != declared:
            findings.append(Finding(
                "RA2", DOCS, row.line,
                f"{type_!r} fields drifted: docs say {doc_fields}, "
                f"EVENT_TYPES says {declared}",
                key=f"RA2:docs-fields:{type_}"))
    return findings
