"""Sharding rules: parameter / optimizer / cache / input PartitionSpecs.

Policy (baseline, hillclimbed in EXPERIMENTS.md §Perf):
  * TP over the ``model`` axis: attention heads, FFN hidden dim, vocab.
  * FSDP over ``data`` for archs flagged ``cfg.fsdp`` (big dense weights get
    their non-TP dim sharded over data; XLA inserts all-gathers).
  * EP: expert dim over ``model`` when divisible; for very large expert
    counts (DeepSeek-V3) over ``(model, data)`` jointly (1 expert/device).
  * DP: batch over ``(pod, data)`` (or whatever prefix divides the batch).
  * KV caches: heads over ``model`` when divisible, otherwise the sequence
    dim (flash-decoding-style sharded-KV softmax), batch over data axes.

All rules are *hints*: GSPMD preserves correctness regardless; these choices
drive the collective schedule measured in the roofline.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.models import model as model_lib
from repro.models.common import SHAPE_CASES, ShapeCase
from repro.models.config import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _path_keys(path) -> list[str]:
    keys = []
    for e in path:
        if isinstance(e, DictKey):
            keys.append(str(e.key))
        elif isinstance(e, SequenceKey):
            keys.append(f"[{e.idx}]")
    return keys


def batch_axes(mesh: Mesh, batch: int) -> tuple[str, ...]:
    """Longest prefix of (pod, data) whose product divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen: list[str] = []
    size = 1
    for a in sorted(axes, key=lambda a: a != "data"):  # prefer data first
        if batch % (size * _axis_size(mesh, a)) == 0:
            chosen.append(a)
            size *= _axis_size(mesh, a)
    return tuple(chosen)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig, mesh: Mesh, path, leaf) -> P:
    keys = _path_keys(path)
    name = keys[-1]
    shape = leaf.shape
    # scan-over-layers stacks non-shared slot params along a leading repeat
    # axis: rules must apply to shape[1:], with the repeat dim replicated
    stacked = False
    if len(keys) >= 4 and keys[0] == "groups" and keys[2] == "slots":
        gi = int(keys[1].strip("[]"))
        si = int(keys[3].strip("[]"))
        stacked = not cfg.groups[gi].pattern[si].shared
    if stacked:
        shape = shape[1:]
    entries = tuple(_spec_entries(cfg, mesh, keys, name, shape))
    if stacked:
        entries = (None,) + entries
    return P(*entries)


def _spec_entries(cfg: ModelConfig, mesh: Mesh, keys, name, shape) -> tuple:
    tp = _axis_size(mesh, "model")
    dp = _axis_size(mesh, "data")
    fsdp = "data" if cfg.fsdp else None

    def ok(dim, ax):  # divisibility check for an axis name
        n = _axis_size(mesh, ax) if ax else 1
        return ax is not None and shape[dim] % n == 0 and n > 1

    # --- embeddings / heads ---
    if name == "embed":
        if cfg.num_codebooks:
            return (None, "model" if ok(1, "model") else None, None)
        return ("model" if ok(0, "model") else None, None)
    if name == "head":
        if cfg.num_codebooks:
            return (None, None, "model" if ok(2, "model") else None)
        return (None, "model" if ok(1, "model") else None)

    # --- MoE experts ---
    if "experts" in keys:
        e = cfg.moe.num_experts
        mode = cfg.moe_sharding
        if mode == "auto":
            if e % (tp * dp) == 0 and tp * dp > 1:
                mode = "ep2d"
            elif e % tp == 0 and tp > 1:
                mode = "ep_fsdp" if cfg.fsdp else "ep"
            else:
                mode = "tp"
        if mode == "ep2d" and e % (tp * dp) == 0:     # EP over model+data
            return (("model", "data"), None, None)
        if mode == "ep_fsdp" and e % tp == 0:         # EP(model)+FSDP(data)
            return ("model", "data" if ok(1, "data") else None, None)
        if mode == "ep" and e % tp == 0 and tp > 1:   # EP over model
            return ("model", fsdp if ok(1, fsdp) else None, None)
        # TP inside experts: shard the F dim (dim2 for wi/wu, dim1 for wo)
        if name in ("wi", "wu"):
            return (None, fsdp if ok(1, fsdp) else None,
                     "model" if ok(2, "model") else None)
        return (None, "model" if ok(1, "model") else None,
                 fsdp if ok(2, fsdp) else None)
    if "router" in keys:
        return ()

    # --- mamba / xlstm (small models: replicate projections) ---
    if name in ("in_proj", "out_proj", "conv_w", "conv_b", "a_log",
                "dt_bias", "d_skip", "r", "w_gates", "up", "down",
                "up_gate", "w_in"):
        return tuple([None] * len(shape))

    # --- fused projections (beyond-paper perf knobs) ---
    if name == "wqkv":
        return (fsdp if ok(0, fsdp) else None,
                "model" if ok(1, "model") else None)
    if name == "wgu":  # (D, 2, F)
        return (fsdp if ok(0, fsdp) else None, None,
                "model" if ok(2, "model") else None)

    # --- attention / MLP 2D weights ---
    if name in ("wq", "wq_b", "wk_b", "wv_b"):
        return (fsdp if ok(0, fsdp) else None,
                 "model" if ok(1, "model") else None)
    if name in ("wk", "wv"):
        # shard KV projection over model only if kv heads divide tp
        kv_ok = cfg.num_kv_heads % tp == 0 and tp > 1 and ok(1, "model")
        return (fsdp if ok(0, fsdp) else None, "model" if kv_ok else None)
    if name == "wo":
        return ("model" if ok(0, "model") else None,
                 fsdp if ok(1, fsdp) else None)
    if name in ("wi", "wu"):
        return (fsdp if ok(0, fsdp) else None,
                 "model" if ok(1, "model") else None)
    if name in ("wq_a", "wkv_a"):
        return (fsdp if ok(0, fsdp) else None, None)

    # norms, gates, scalars
    return tuple([None] * len(shape))


def param_shardings(cfg: ModelConfig, mesh: Mesh) -> Any:
    shapes = model_lib.abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_spec(cfg, mesh, p, l)), shapes)


def abstract_sharded_params(cfg: ModelConfig, mesh: Mesh) -> Any:
    shapes = model_lib.abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jax.ShapeDtypeStruct(
            l.shape, l.dtype,
            sharding=NamedSharding(mesh, param_spec(cfg, mesh, p, l))),
        shapes)


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, mesh: Mesh, batch: int, path, leaf) -> P:
    keys = _path_keys(path)
    name = keys[-1]
    tp = _axis_size(mesh, "model")
    b_axes = batch_axes(mesh, batch)
    b = b_axes if b_axes else None
    shape = leaf.shape  # leading dims: (repeat, B, ...)

    if name in ("k", "v"):            # (R, B, S, KV, hd) attention cache
        kv = shape[3]
        if kv % tp == 0 and tp > 1:
            return P(None, b, None, "model", None)
        if shape[2] % tp == 0 and tp > 1:
            return P(None, b, "model", None, None)  # shard sequence
        return P(None, b, None, None, None)
    if name == "ckv":                 # (R, B, S, r) MLA latent
        if shape[3] % tp == 0 and tp > 1:
            return P(None, b, None, "model")
        return P(None, b, None, None)
    if name == "krope":
        return P(None, b, None, None)
    if name == "ssm":                 # (R, B, NH, HD, NS)
        if shape[2] % tp == 0 and tp > 1:
            return P(None, b, "model", None, None)
        return P(None, b, None, None, None)
    if name == "conv":                # (R, B, K-1, conv_dim)
        return P(None, b, None, "model" if shape[3] % tp == 0 and tp > 1
                 else None)
    if name in ("c", "n", "h", "m"):  # xLSTM states (R, B, NH, ...)
        return P(None, b, *([None] * (len(shape) - 2)))
    if name == "filled":
        return P(*([None] * len(shape)))
    return P(None, b, *([None] * (len(shape) - 2)))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int,
                    max_len: int) -> Any:
    shapes = model_lib.abstract_cache(cfg, batch, max_len)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jax.ShapeDtypeStruct(
            l.shape, l.dtype,
            sharding=NamedSharding(mesh, cache_spec(cfg, mesh, batch, p, l))),
        shapes)


# ---------------------------------------------------------------------------
# Input specs (the assigned shape cells)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, case: ShapeCase | str, mesh: Mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    if isinstance(case, str):
        case = SHAPE_CASES[case]
    b, s = case.global_batch, case.seq_len
    b_axes = batch_axes(mesh, b) or None
    seq_axes = None
    if b_axes is None and s % _axis_size(mesh, "data") == 0:
        seq_axes = "data"  # long-context batch=1: shard sequence

    def tok(shape):
        return jax.ShapeDtypeStruct(
            shape, jnp.int32,
            sharding=NamedSharding(mesh, P(b_axes, *([seq_axes] +
                                           [None] * (len(shape) - 2)))))

    out: dict = {}
    tok_shape = ((b, s, cfg.num_codebooks) if cfg.num_codebooks else (b, s))
    if case.kind == "train":
        out["tokens"] = tok(tok_shape)
        out["labels"] = tok(tok_shape)
    elif case.kind == "prefill":
        out["tokens"] = tok(tok_shape)
    else:  # decode: one new token against a seq_len cache
        one = ((b, 1, cfg.num_codebooks) if cfg.num_codebooks else (b, 1))
        out["tokens"] = jax.ShapeDtypeStruct(
            one, jnp.int32, sharding=NamedSharding(
                mesh, P(b_axes, *([None] * (len(one) - 1)))))
        out["pos"] = jax.ShapeDtypeStruct(
            (b,), jnp.int32, sharding=NamedSharding(mesh, P(b_axes)))
    if cfg.vision_dim and case.kind != "decode":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.vision_dim), cfg.compute_dtype,
            sharding=NamedSharding(mesh, P(b_axes, None, None)))
    return out
