"""Logical-axis sharding hints.

Model code calls ``hint(x, 'batch', 'seq', 'heads', None)`` at key points;
inside a ``logical_rules`` context each logical name maps to a mesh axis (or
tuple of axes, or None) and the hint becomes a
``jax.lax.with_sharding_constraint``.  Outside any context it is a no-op,
so single-device smoke tests and kernels never see mesh machinery.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def current_rules():
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def logical_rules(mesh: Mesh, rules: dict[str, Any]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.rules = prev


def hint(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (None = replicated
    on that dim).  No-op outside a ``logical_rules`` context."""
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = []
    used: set = set()
    for a in axes:
        m = rules.get(a) if a is not None else None
        # drop axes already consumed by an earlier dim (GSPMD disallows reuse)
        if m is not None:
            flat = m if isinstance(m, tuple) else (m,)
            if any(f in used for f in flat):
                m = None
            else:
                used.update(flat)
        # divisibility guard
        if m is not None:
            flat = m if isinstance(m, tuple) else (m,)
            size = 1
            for f in flat:
                size *= mesh.shape[f]
            dim = len(spec)
            if x.shape[dim] % size != 0:
                m = None
        spec.append(m)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def make_rules(cfg, mesh: Mesh, batch: int) -> dict[str, Any]:
    """Default logical->mesh mapping for a model config on a mesh."""
    from repro.parallel.sharding import batch_axes  # lazy: avoid cycle
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    b_axes = batch_axes(mesh, batch)
    rules: dict[str, Any] = {
        "batch": b_axes if b_axes else None,
        # sequence parallelism (Megatron-SP style, validated in §Perf):
        # shard the residual stream's seq dim over TP so norm/residual
        # traffic divides by TP; all-reduces become all-gather/scatter
        "seq": "model" if cfg.seq_parallel else None,
        "heads": "model" if cfg.num_heads % tp == 0 else None,
        "kv_heads": "model" if cfg.num_kv_heads % tp == 0 else None,
        # sequence-parallel attention fallback: when the head count does
        # not divide TP, shard the query sequence dim instead (bounds the
        # (B,H,S,T) score tensor; the hint's divisibility guard makes this
        # a no-op for decode's S=1)
        "attn_seq": "model" if cfg.num_heads % tp != 0 else None,
        "ffn": "model",
        "vocab": "model",
        "embed": None,
        # weight-side logical axes: hints on weights at their use sites act
        # as just-in-time FSDP all-gathers (wt_d strips the 'data' shard)
        "wt_d": None,
        "heads_out": "model" if cfg.num_heads % tp == 0 else None,
        "kv_out": "model" if cfg.num_kv_heads % tp == 0 else None,
    }
    if cfg.moe is not None:
        e = cfg.moe.num_experts
        dp = mesh.shape.get("data", 1)
        mode = cfg.moe_sharding
        if mode == "auto":
            if e % (tp * dp) == 0 and tp * dp > 1:
                mode = "ep2d"
            elif e % tp == 0 and tp > 1:
                mode = "ep_fsdp" if cfg.fsdp else "ep"
            else:
                mode = "tp"
        if mode == "ep2d" and e % (tp * dp) == 0 and tp * dp > 1:
            rules["experts"] = ("model", "data")
            rules["expert_ffn"] = None
            rules["moe_groups"] = None
        elif mode in ("ep", "ep_fsdp") and e % tp == 0 and tp > 1:
            # EP over model; expert weights FSDP-gathered over data at use
            rules["experts"] = "model"
            rules["expert_ffn"] = None
            rules["moe_groups"] = b_axes if b_axes else None
        else:
            rules["experts"] = None
            rules["expert_ffn"] = "model"
            rules["moe_groups"] = b_axes if b_axes else None
    if cfg.mamba is not None:
        d_inner = cfg.mamba.expand * cfg.d_model
        nh = d_inner // cfg.mamba.head_dim
        rules["mamba_heads"] = "model" if nh % tp == 0 else None
        rules["d_inner"] = "model" if d_inner % tp == 0 else None
    return rules
