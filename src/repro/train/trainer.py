"""Training drivers.

:class:`Trainer` — the standard single-controller loop: jitted train step,
prefetched data, periodic async checkpoints and evals, exact restart from
the latest checkpoint (data pipeline included, since batches are a pure
function of step).

:class:`MicrobatchCoordinator` — the paper-integration path: each global
step becomes a task graph (M microbatch-gradient tasks -> 1 reduce+update
task) submitted as an epoch to one persistent :class:`repro.core.client.
Cluster`, so back-to-back steps reuse the warm executor pool instead of
restarting it.  The work-stealing scheduler rebalances microbatches away
from stragglers, and executor failure mid-step resubmits the lost
microbatches — the paper's mechanisms doing real training work.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import Cluster
from repro.core.graph import Task, TaskGraph
from repro.ckpt import checkpoint as ckpt_lib
from repro.data.pipeline import PrefetchPipeline, SyntheticDataset
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.train.optimizer import Optimizer, make_optimizer
from repro.train.train_step import make_loss_fn, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_every: int = 50
    eval_every: int = 50
    ckpt_dir: str = ""
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig,
                 optimizer: Optimizer | None = None):
        self.cfg = cfg
        self.tc = tc
        self.opt = optimizer or make_optimizer(cfg.optimizer)
        key = jax.random.PRNGKey(tc.seed)
        self.params = model_lib.init_params(key, cfg)
        self.opt_state = self.opt.init(self.params)
        self.step = 0
        self.dataset = SyntheticDataset(cfg, tc.global_batch, tc.seq_len,
                                        tc.seed)
        self._train_step = jax.jit(make_train_step(cfg, self.opt))
        self._eval_step = jax.jit(
            lambda p, b: make_loss_fn(cfg)(p, b)[1]["loss"])
        self.ckptr = (ckpt_lib.AsyncCheckpointer(tc.ckpt_dir, tc.keep_ckpts)
                      if tc.ckpt_dir else None)
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def maybe_restore(self) -> bool:
        if not self.tc.ckpt_dir:
            return False
        step = ckpt_lib.latest_step(self.tc.ckpt_dir)
        if step is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        restored, step, _ = ckpt_lib.restore(self.tc.ckpt_dir, tree, step)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = step
        return True

    def train(self, steps: int | None = None) -> list[dict]:
        steps = steps or self.tc.steps
        pipe = PrefetchPipeline(self.dataset, depth=2, n_loaders=2,
                                start_step=self.step)
        try:
            while self.step < steps:
                step_id, batch = pipe.get()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = self._train_step(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                self.step = step_id + 1
                rec = {"step": self.step, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "time_s": time.perf_counter() - t0}
                self.history.append(rec)
                if self.ckptr and self.step % self.tc.ckpt_every == 0:
                    self.ckptr.save(self.step,
                                    {"params": self.params,
                                     "opt": self.opt_state},
                                    meta={"config": self.cfg.name})
                if self.step % self.tc.eval_every == 0:
                    eb = {k: jnp.asarray(v) for k, v in
                          self.dataset.batch_at(10_000_000 + self.step
                                                ).items()}
                    rec["eval_loss"] = float(self._eval_step(self.params,
                                                             eb))
                if self.step % self.tc.log_every == 0:
                    print(f"step {self.step:5d} loss {loss:.4f} "
                          f"({rec['time_s']*1e3:.0f} ms)")
        finally:
            pipe.stop()
            if self.ckptr:
                self.ckptr.wait()
        return self.history


# ---------------------------------------------------------------------------
# Microbatch dispatch through the paper's runtime
# ---------------------------------------------------------------------------

class MicrobatchCoordinator:
    """One training step = one graph epoch on a persistent Cluster.

    Executors are runtime workers (stand-ins for pods); each microbatch
    gradient is a task; the final task averages gradients and applies the
    optimizer.  The Cluster outlives the step loop, so the 2nd..Nth step
    submit onto warm executors (no pool restart between steps — the whole
    point of the paper's long-lived server).  ``slow_workers`` makes
    chosen executors straggle so the work-stealing scheduler's
    rebalancing is observable.

    Because the pool is shared across steps, an executor killed via
    ``fail_worker`` stays dead for the coordinator's lifetime (later
    steps run on the surviving executors) — a real long-lived deployment
    would replace it; elastic replacement of process/thread executors is
    a ROADMAP item.
    """

    #: default byte bound on the coordinator's pool (ROADMAP PR-5
    #: follow-up: trainer/serving pools are bounded like everyone
    #: else's).  Microbatch tasks return small ints (gradients ride the
    #: closure), so the bound is slack in practice.
    DEFAULT_MEMORY_LIMIT = 256 * 2**20

    def __init__(self, cfg: ModelConfig, *, n_executors: int = 4,
                 n_microbatches: int = 8, scheduler: str = "rsds_ws",
                 slow_workers: dict[int, float] | None = None,
                 seed: int = 0,
                 memory_limit: int | None = DEFAULT_MEMORY_LIMIT,
                 events=None):
        self.cfg = cfg
        self.n_executors = n_executors
        self.n_micro = n_microbatches
        self.scheduler_name = scheduler
        self.slow = slow_workers or {}
        self.memory_limit = memory_limit
        self._events = events
        self.opt = make_optimizer(cfg.optimizer)
        key = jax.random.PRNGKey(seed)
        self.params = model_lib.init_params(key, cfg)
        self.opt_state = self.opt.init(self.params)
        loss_fn = make_loss_fn(cfg)
        self._grad = jax.jit(
            lambda p, b: jax.value_and_grad(
                lambda q: loss_fn(q, b)[0])(p))
        self.step = 0
        self.steal_count = 0
        self._cluster: Cluster | None = None

    # ------------------------------------------------------------------
    def _ensure_cluster(self) -> Cluster:
        if self._cluster is not None:
            return self._cluster
        server = "dask" if self.scheduler_name.startswith("dask") else \
            "rsds"
        sched = {"rsds_ws": "ws", "dask_ws": "ws", "ws": "ws",
                 "random": "random", "heft": "heft"}[self.scheduler_name]
        c = Cluster(server=server, scheduler=sched,
                    n_workers=self.n_executors, runtime="thread",
                    name="microbatch", balance_interval=0.002,
                    timeout=120.0, autostart=False,
                    memory_limit=self.memory_limit,
                    events=self._events)
        rt = c.runtime
        if self.slow:
            orig = rt._worker_loop

            def slow_loop(wid):
                if wid not in self.slow:
                    return orig(wid)
                inbox = rt.worker_inbox[wid]
                while True:
                    item = inbox.get()
                    if item is None:
                        return
                    if wid in rt.dead:
                        continue
                    with rt._lock:
                        if item in rt.queued.get(wid, []):
                            rt.queued[wid].remove(item)
                        else:
                            # retracted (stolen) while waiting in the
                            # inbox: skip without paying the straggler
                            # delay, or ghosts of a previous epoch's
                            # stolen tasks would stall the next one
                            continue
                    time.sleep(self.slow[wid])
                    t = rt.g.task(item)
                    if t.fn is not None:
                        args = [rt.results.get(d) for d in t.inputs]
                        rt.results[item] = t.fn(*args) if t.args == () \
                            else t.fn(*t.args)
                    rt.server_inbox.put(("finished", item, wid))

            rt._worker_loop = slow_loop
        c.start()
        self._cluster = c
        return c

    def close(self) -> None:
        if self._cluster is not None:
            self._cluster.close()
            self._cluster = None

    def _make_step_graph(self, batch: dict) -> TaskGraph:
        mb = {k: np.array_split(v, self.n_micro) for k, v in batch.items()}
        tasks = []
        losses = [0.0] * self.n_micro
        grads: list = [None] * self.n_micro

        def run_micro(i):
            def fn():
                # straggler injection happens per-executor in the runtime
                loss, g = self._grad(self.params,
                                     {k: jnp.asarray(v[i])
                                      for k, v in mb.items()})
                losses[i] = float(loss)
                grads[i] = g
                return i
            return fn

        for i in range(self.n_micro):
            tasks.append(Task(i, (), duration=1e-3, output_size=1024,
                              fn=run_micro(i), name=f"micro-{i}"))

        def reduce_fn(*_):
            gsum = grads[0]
            for g in grads[1:]:
                gsum = jax.tree.map(jnp.add, gsum, g)
            gmean = jax.tree.map(lambda x: x / self.n_micro, gsum)
            self.params, self.opt_state, om = self.opt.apply(
                self.params, gmean, self.opt_state)
            return float(np.mean(losses))

        tasks.append(Task(self.n_micro, tuple(range(self.n_micro)),
                          duration=1e-3, output_size=8, fn=reduce_fn,
                          name="reduce"))
        return TaskGraph(tasks, name=f"train-step-{self.step}")

    def train_step(self, batch: dict, *, fail_worker: int | None = None
                   ) -> dict:
        cluster = self._ensure_cluster()
        graph = self._make_step_graph(batch)
        if fail_worker is not None:
            def _killer():
                time.sleep(0.01)
                cluster.runtime.fail_worker(fail_worker)
            threading.Thread(target=_killer, daemon=True).start()
        futs = cluster.client.submit_graph(graph)
        ok = futs.wait(120.0)
        epoch = futs.epoch
        loss = futs.raw_results().get(self.n_micro) if ok else None
        futs.release()   # per-step values are consumed; free the keys
        self.step += 1
        ev = cluster.events
        if ev is not None:
            ev.publish("train-step", step=self.step,
                       makespan=epoch.makespan)
        return {"step": self.step, "loss": loss,
                "makespan": epoch.makespan, "timed_out": not ok,
                "server_busy": epoch.server_busy}
