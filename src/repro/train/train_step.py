"""Loss and train-step builders (fwd + bwd + optimizer update), with
optional microbatch gradient accumulation and MoE aux-loss wiring.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.train.optimizer import Optimizer


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy.  logits: (B,S,V) or (B,S,K,V)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        loss, aux = model_lib.forward_loss(params, cfg, batch["tokens"],
                                           batch["labels"],
                                           batch.get("image_embeds"))
        total = loss + aux["moe_aux_loss"]
        metrics = {"loss": loss, "moe_aux_loss": aux["moe_aux_loss"],
                   "moe_dropped": aux["moe_dropped"]}
        return total, metrics
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: Optimizer, *,
                    grad_accum: int = 1):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, opt_state, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        params, opt_state, om = opt.apply(params, grads, opt_state)
        metrics.update(om)
        return params, opt_state, metrics

    if grad_accum == 1:
        return single

    def accumulated(params, opt_state, batch):
        def split(x):
            b = x.shape[0]
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            gsum, lsum = carry
            (loss, _), grads = grad_fn(params, mb)
            gsum = jax.tree.map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        params, opt_state, om = opt.apply(params, grads, opt_state)
        om["loss"] = lsum / grad_accum
        return params, opt_state, om

    return accumulated


def make_eval_step(cfg: ModelConfig):
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return eval_step
