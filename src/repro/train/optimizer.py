"""Optimizers: AdamW, Adafactor (factored second moments — the
memory-frugal choice for the 300B+ archs), and Lion.

Functional API: ``opt.init(params) -> state``; ``opt.apply(params, grads,
state) -> (new_params, new_state, metrics)``.  ``opt.abstract_state``
builds ShapeDtypeStructs with NamedShardings derived from the parameter
specs so the dry-run can lower a full train_step without allocating.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(c: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(c.warmup, 1), 1.0)
    t = jnp.clip((step - c.warmup) / jnp.maximum(c.decay_steps - c.warmup, 1),
                 0.0, 1.0)
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return c.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def _map_unzip(fn, *trees):
    """Map ``fn`` (returning a tuple) over leaves and unzip into one tree
    per output.  Flatten-based, so tuples *inside* the tree structure (e.g.
    group slots) never get mistaken for packed leaves."""
    flat0, tree = jax.tree_util.tree_flatten(trees[0])
    rest = [tree.flatten_up_to(t) for t in trees[1:]]
    outs = [fn(*xs) for xs in zip(flat0, *rest)]
    width = len(outs[0]) if outs else 0
    return tuple(tree.unflatten([o[i] for o in outs])
                 for i in range(width))


class Optimizer:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def init(self, params) -> Any:
        raise NotImplementedError

    def apply(self, params, grads, state) -> tuple[Any, Any, dict]:
        raise NotImplementedError

    def abstract_state(self, abstract_params, mesh=None) -> Any:
        state = jax.eval_shape(self.init, abstract_params)
        if mesh is None:
            return state
        return _attach_state_shardings(state, abstract_params, mesh)


def _attach_state_shardings(state, abstract_params, mesh):
    """Mirror param shardings onto state trees; reduced-rank leaves
    (adafactor row/col stats) drop the matching trailing spec entries."""
    flat_p = {tuple(str(k) for k in path): leaf
              for path, leaf in
              jax.tree_util.tree_flatten_with_path(abstract_params)[0]}

    def fix(path, leaf):
        keys = tuple(str(k) for k in path)
        spec: tuple = ()
        ref = None
        for start in range(len(keys)):
            ref = flat_p.get(keys[start:]) or flat_p.get(keys[start:-1])
            if ref is not None:
                break
        if ref is not None and getattr(ref, "sharding", None) is not None:
            pspec = tuple(ref.sharding.spec)
            pspec = pspec + (None,) * (len(ref.shape) - len(pspec))
            if leaf.shape == ref.shape:
                spec = pspec
            elif leaf.shape == ref.shape[:-1]:
                spec = pspec[:-1]                      # row stats
            elif len(ref.shape) >= 2 \
                    and leaf.shape == ref.shape[:-2] + ref.shape[-1:]:
                spec = pspec[:-2] + pspec[-1:]         # col stats
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, P(*spec)))

    return jax.tree_util.tree_map_with_path(fix, state)


class AdamW(Optimizer):
    def init(self, params):
        zeros = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return {"m": zeros(params), "v": zeros(params),
                "step": jnp.zeros((), jnp.int32)}

    def apply(self, params, grads, state):
        c = self.cfg
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        lr = schedule(c, step)
        bc1 = 1 - c.b1 ** step.astype(jnp.float32)
        bc2 = 1 - c.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m = c.b1 * m + (1 - c.b1) * g
            v = c.b2 * v + (1 - c.b2) * jnp.square(g)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + c.eps)
            u = u + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        new_params, new_m, new_v = _map_unzip(upd, params, grads,
                                              state["m"], state["v"])
        return (new_params, {"m": new_m, "v": new_v, "step": step},
                {"grad_norm": gnorm, "lr": lr})


class Adafactor(Optimizer):
    """Momentum-free Adafactor with factored second moments for rank>=2."""

    def init(self, params):
        def stat(p):
            if len(p.shape) >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(stat, params),
                "step": jnp.zeros((), jnp.int32)}

    def apply(self, params, grads, state):
        c = self.cfg
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        lr = schedule(c, step)
        decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

        def upd(p, g, s):
            g2 = jnp.square(g) + 1e-30
            if "vr" in s:
                vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / (jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                       + 1e-30))
                u = g / (denom + c.eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                u = g / (jnp.sqrt(v) + c.eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms)  # update clipping (RMS <= 1)
            u = u + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        new_params, new_stats = _map_unzip(upd, params, grads,
                                           state["stats"])
        return (new_params, {"stats": new_stats, "step": step},
                {"grad_norm": gnorm, "lr": lr})


class Lion(Optimizer):
    def init(self, params):
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def apply(self, params, grads, state):
        c = self.cfg
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        lr = schedule(c, step)

        def upd(p, g, m):
            u = jnp.sign(c.b1 * m + (1 - c.b1) * g)
            u = u + c.weight_decay * p.astype(jnp.float32)
            m2 = c.b2 * m + (1 - c.b2) * g
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2

        new_params, new_m = _map_unzip(upd, params, grads, state["m"])
        return (new_params, {"m": new_m, "step": step},
                {"grad_norm": gnorm, "lr": lr})


def make_optimizer(name: str, **kw) -> Optimizer:
    cfg = OptConfig(name=name, **kw)
    return {"adamw": AdamW, "adafactor": Adafactor,
            "lion": Lion}[name](cfg)
