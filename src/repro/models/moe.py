"""Mixture-of-Experts FFN: grouped GShard-style top-k capacity dispatch.

Supports Grok-1-style softmax top-2 over 8 experts and DeepSeek-V3-style
sigmoid top-8 over 256 routed + shared experts with aux-loss-free bias
routing.

Tokens are reshaped into dispatch groups of ~``GROUP_SIZE`` tokens so the
one-hot dispatch/combine tensors stay O(S_g^2) per group instead of O(T^2);
groups shard over the data axes, experts shard over the model (and, for very
large expert counts, also the data) axis — see repro.parallel.sharding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.models.common import ACTIVATIONS, dense_init, take_keys
from repro.models.config import ModelConfig
from repro.models.mlp import apply_mlp, init_mlp
from repro.parallel.annotate import hint

Params = Any
GROUP_SIZE = 2048


def init_moe(key, cfg: ModelConfig) -> Params:
    dt = cfg.compute_dtype
    m = cfg.moe
    d = cfg.d_model
    f = m.d_expert or cfg.d_ff
    k_r, k_e, k_s = take_keys(key, 3)
    ke1, ke2, ke3 = take_keys(k_e, 3)
    p = {
        "router": {"w": dense_init(k_r, d, (m.num_experts,), jnp.float32)},
        "experts": {
            "wi": _stack_init(ke1, m.num_experts, d, f, dt),
            "wu": _stack_init(ke2, m.num_experts, d, f, dt),
            "wo": _stack_init(ke3, m.num_experts, f, d, dt),
        },
    }
    if m.router_bias:
        p["router"]["bias"] = jnp.zeros((m.num_experts,), jnp.float32)
    if m.num_shared:
        p["shared"] = init_mlp(k_s, cfg, d_ff=f * m.num_shared)
    return p


def _stack_init(key, e: int, din: int, dout: int, dt) -> jax.Array:
    keys = jax.random.split(key, e)
    return jax.vmap(lambda k: dense_init(k, din, (dout,), dt))(keys)


def _group(tokens: jax.Array, group_size: int = GROUP_SIZE) -> jax.Array:
    t = tokens.shape[0]
    sg = group_size if t % group_size == 0 else t
    return tokens.reshape(t // sg, sg, tokens.shape[-1])


def apply_moe(params: Params, cfg: ModelConfig, x: jax.Array
              ) -> tuple[jax.Array, dict]:
    """x: (B, S, D) -> (y, aux) where aux carries load-balance stats."""
    m = cfg.moe
    b, s, d = x.shape
    act = ACTIVATIONS[cfg.activation]
    xt = _group(x.reshape(b * s, d), m.group_size)   # (G, Sg, D)
    g, sg, _ = xt.shape
    e = m.num_experts
    cap = max(int(sg * m.top_k * m.capacity_factor / e), 1)
    cap = min(cap, sg)

    logits = jnp.einsum("gsd,de->gse", xt, params["router"]["w"]
                        ).astype(jnp.float32)
    bias = params["router"].get("bias")
    if bias is not None:
        bias = jax.lax.stop_gradient(bias)
    weights, idx = jax.vmap(
        lambda lg: kref.topk_gating(lg, m.top_k, router=m.router, bias=bias)
    )(logits)                                  # (G,Sg,K), (G,Sg,K)

    # Capacity-limited one-hot dispatch (GShard): earlier tokens win slots.
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)        # (G,Sg,K,E)
    # priority: k=0 choices across all tokens first, then k=1, ...
    prio = jnp.moveaxis(onehot, 2, 1).reshape(g, m.top_k * sg, e)
    pos = jnp.cumsum(prio, axis=1) - 1                       # slot per (k,t)
    pos = jnp.moveaxis(pos.reshape(g, m.top_k, sg, e), 1, 2)  # (G,Sg,K,E)
    keep = (pos < cap) & (onehot > 0)
    slot = jnp.where(keep, pos, 0)
    disp = (jax.nn.one_hot(slot, cap, dtype=xt.dtype)
            * keep[..., None].astype(xt.dtype))              # (G,Sg,K,E,C)
    comb = disp * weights[..., None, None].astype(xt.dtype)
    disp = disp.sum(axis=2)                                  # (G,Sg,E,C)
    comb = comb.sum(axis=2)

    xin = jnp.einsum("gsec,gsd->gecd", disp, xt)             # (G,E,C,D)
    xin = hint(xin, "moe_groups", "experts", None, None)
    wi = hint(params["experts"]["wi"], "experts", "wt_d", "expert_ffn")
    wu = hint(params["experts"]["wu"], "experts", "wt_d", "expert_ffn")
    wo = hint(params["experts"]["wo"], "experts", "expert_ffn", "wt_d")
    h = act(jnp.einsum("gecd,edf->gecf", xin, wi))
    h = h * jnp.einsum("gecd,edf->gecf", xin, wu)
    h = hint(h, "moe_groups", "experts", None, "expert_ffn")
    xout = jnp.einsum("gecf,efd->gecd", h, wo)
    xout = hint(xout, "moe_groups", "experts", None, None)
    y = jnp.einsum("gsec,gecd->gsd", comb, xout)
    y = hint(y, "moe_groups", None, None)

    # load-balance stats (Switch aux loss + DSv3 bias-update signal)
    probs = (jax.nn.softmax(logits, axis=-1) if m.router == "softmax"
             else jax.nn.sigmoid(logits))
    frac_tokens = jnp.mean(onehot.sum(axis=2).astype(jnp.float32),
                           axis=(0, 1))                      # (E,)
    frac_prob = jnp.mean(probs, axis=(0, 1))
    aux_loss = e * jnp.sum(frac_tokens * frac_prob) * m.aux_loss_weight
    dropped = 1.0 - jnp.sum(disp) / (g * sg * m.top_k)
    aux = {"moe_aux_loss": aux_loss, "moe_load": frac_tokens,
           "moe_dropped": dropped}

    if m.num_shared:
        y = y + apply_mlp(params["shared"], cfg, xt)
    return y.reshape(b, s, d), aux
