"""Mamba-2 (SSD) block: fused in-projection, causal depthwise conv, chunked
state-space scan, gated RMSNorm, out-projection.

The chunked scan does intra-chunk work as dense matmuls and propagates
inter-chunk states with ``jax.lax.associative_scan`` (log-depth, no while
loop) so compiled FLOPs are fully visible to ``cost_analysis`` — see
DESIGN.md (scan cost-accounting).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, rmsnorm_init, take_keys
from repro.models.config import ModelConfig
from repro.parallel.annotate import hint

Params = Any


def _dims(cfg: ModelConfig):
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    nh = d_inner // mc.head_dim
    return d_inner, nh, mc.d_state, mc.d_conv


def init_mamba2(key, cfg: ModelConfig, spec=None) -> Params:
    dt = cfg.compute_dtype
    d_inner, nh, ns, k = _dims(cfg)
    conv_dim = d_inner + 2 * ns
    ks = take_keys(key, 4)
    return {
        # fused in-proj -> [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], cfg.d_model,
                              (2 * d_inner + 2 * ns + nh,), dt),
        "conv_w": (jax.random.normal(ks[1], (k, conv_dim)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dt),
        "out_proj": dense_init(ks[2], d_inner, (cfg.d_model,), dt),
    }


def init_mamba_cache(cfg: ModelConfig, spec, batch: int, max_len: int,
                     dtype) -> Params:
    d_inner, nh, ns, k = _dims(cfg)
    conv_dim = d_inner + 2 * ns
    return {
        "conv": jnp.zeros((batch, k - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.mamba.head_dim, ns), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B,S,C), w: (K,C). Returns (y, new_tail)."""
    k = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_tail = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(y + b[None, None]), new_tail


def _ssd_chunked(x, dtv, a, bmat, cmat, d_skip, h0, chunk: int):
    """Chunked SSD. x:(B,S,NH,HD) dtv:(B,S,NH) bmat/cmat:(B,S,NS) a:(NH,).

    Returns (y, h_final:(B,NH,HD,NS))."""
    bsz, s, nh, hd = x.shape
    ns = bmat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q
    xr = x.reshape(bsz, nc, q, nh, hd).astype(jnp.float32)
    dtr = dtv.reshape(bsz, nc, q, nh).astype(jnp.float32)
    br = bmat.reshape(bsz, nc, q, ns).astype(jnp.float32)
    cr = cmat.reshape(bsz, nc, q, ns).astype(jnp.float32)

    logdec = dtr * a[None, None, None]                  # (B,NC,Q,NH) <= 0
    fcum = jnp.cumsum(logdec, axis=2)                   # within-chunk cumsum
    ftot = fcum[:, :, -1]                               # (B,NC,NH)

    # intra-chunk: scores[t,u] = (C_t . B_u) * exp(F_t - F_u) * dt_u, u <= t
    cb = jnp.einsum("bcqn,bcun->bcqu", cr, br)          # (B,NC,Q,Q)
    gap = fcum[:, :, :, None, :] - fcum[:, :, None, :, :]   # (B,NC,Q,Q,NH)
    tri = jnp.tril(jnp.ones((q, q), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(gap), 0.0)
    w = w * cb[..., None] * dtr[:, :, None, :, :]
    y = jnp.einsum("bcquh,bcuhd->bcqhd", w, xr)

    # chunk states: S_c = sum_u exp(F_Q - F_u) dt_u B_u (x) x_u
    decay_u = jnp.exp(ftot[:, :, None] - fcum)          # (B,NC,Q,NH)
    sc = jnp.einsum("bcuh,bcuhd,bcun->bchdn", decay_u * dtr, xr, br)

    # inter-chunk: H_c = exp(F_Q_c) H_{c-1} + S_c  (associative affine scan)
    adec = jnp.exp(ftot)                                # (B,NC,NH)

    def comb(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2[..., None, None] * b1 + b2

    acc_a, acc_b = jax.lax.associative_scan(comb, (adec, sc), axis=1)
    # exclusive prefix entering chunk c: H_in_c = prod(a_1..c-1) h0 + B_{c-1}
    prod_a = jnp.concatenate(
        [jnp.ones_like(acc_a[:, :1]), acc_a[:, :-1]], axis=1)
    h_in = prod_a[..., None, None] * h0[:, None] + jnp.concatenate(
        [jnp.zeros_like(acc_b[:, :1]), acc_b[:, :-1]], axis=1)

    y = y + jnp.einsum("bcqn,bcqh,bchdn->bcqhd", cr, jnp.exp(fcum), h_in)
    y = y + d_skip[None, None, None, :, None] * xr
    h_final = acc_a[:, -1][..., None, None] * h0 + acc_b[:, -1]
    return y.reshape(bsz, s, nh, hd), h_final


def apply_mamba2(params: Params, cfg: ModelConfig, spec, x: jax.Array,
                 cache: Params | None = None
                 ) -> tuple[jax.Array, Params | None]:
    bsz, s, _ = x.shape
    d_inner, nh, ns, k = _dims(cfg)
    hd = cfg.mamba.head_dim
    proj = jnp.einsum("bsd,dn->bsn", x, params["in_proj"])
    z, xi, bmat, cmat, dtv = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + ns, 2 * d_inner + 2 * ns],
        axis=-1)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32)
                          + params["dt_bias"][None, None])
    a = -jnp.exp(params["a_log"])

    conv_in = jnp.concatenate([xi, bmat, cmat], axis=-1)
    tail = cache["conv"] if cache is not None else None
    conv_out, new_tail = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], tail)
    xi, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + ns], axis=-1)
    xh = hint(xi.reshape(bsz, s, nh, hd), "batch", "seq", "mamba_heads",
              None)

    # pad to a chunk multiple with dt=0 / x=0 tail: decay=exp(0)=1 and a
    # zero input leave the state untouched, so padded rows are inert
    q = min(cfg.mamba.chunk, s) if s > 1 else 1
    pad = (-s) % max(q, 1)

    if s == 1 and cache is not None:  # decode step
        h = cache["ssm"]
        dt1 = dtv[:, 0]                                   # (B,NH)
        decay = jnp.exp(dt1 * a[None])
        dbx = jnp.einsum("bh,bn,bhd->bhdn", dt1,
                         bmat[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        h = h * decay[..., None, None] + dbx
        y = jnp.einsum("bhdn,bn->bhd", h, cmat[:, 0].astype(jnp.float32))
        y = y + params["d_skip"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(bsz, 1, d_inner)
        new_cache = {"conv": new_tail, "ssm": h}
    else:
        h0 = (cache["ssm"] if cache is not None
              else jnp.zeros((bsz, nh, hd, ns), jnp.float32))
        xh_p, dtv_p, b_p, c_p = xh, dtv, bmat, cmat
        if pad:
            zpad = lambda arr: jnp.pad(arr, [(0, 0), (0, pad)]
                                       + [(0, 0)] * (arr.ndim - 2))
            xh_p, dtv_p = zpad(xh), zpad(dtv)
            b_p, c_p = zpad(bmat), zpad(cmat)
        y, hf = _ssd_chunked(xh_p, dtv_p, a, b_p, c_p, params["d_skip"],
                             h0, cfg.mamba.chunk)
        y = y[:, :s].reshape(bsz, s, d_inner)
        new_cache = (None if cache is None
                     else {"conv": new_tail, "ssm": hf})

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, eps=cfg.norm_eps)
    return jnp.einsum("bsn,nd->bsd", y, params["out_proj"]), new_cache
