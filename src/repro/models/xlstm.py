"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, inherently sequential recurrence with block-diagonal recurrent
weights).

The mLSTM chunked path mirrors the Mamba-2 treatment: intra-chunk dense
matmuls + ``associative_scan`` over inter-chunk (C, n) states.  Gate
pre-activations are soft-capped so the unstabilised inter-chunk exponentials
stay in fp32 range (validated against the stabilised quadratic oracle in
kernels/ref.py).  sLSTM keeps a genuine ``lax.scan`` over time — the paper
itself notes it is not parallelisable; its FLOPs are corrected analytically
in the roofline (DESIGN.md).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, rmsnorm_init, soft_cap, take_keys
from repro.models.config import ModelConfig

Params = Any
GATE_CAP = 15.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mdims(cfg: ModelConfig):
    d_inner = int(cfg.xlstm.proj_factor * cfg.d_model)
    nh = cfg.num_heads
    return d_inner, nh, d_inner // nh


def init_mlstm(key, cfg: ModelConfig, spec=None) -> Params:
    dt = cfg.compute_dtype
    d_inner, nh, hd = _mdims(cfg)
    ks = take_keys(key, 6)
    return {
        "up": dense_init(ks[0], cfg.d_model, (2 * d_inner,), dt),  # [x, z]
        "wq": dense_init(ks[1], d_inner, (d_inner,), dt),
        "wk": dense_init(ks[2], d_inner, (d_inner,), dt),
        "wv": dense_init(ks[3], d_inner, (d_inner,), dt),
        "w_gates": dense_init(ks[4], d_inner, (2 * nh,), dt),  # [i, f]
        "norm": rmsnorm_init(d_inner, dt),
        "down": dense_init(ks[5], d_inner, (cfg.d_model,), dt),
    }


def init_mlstm_cache(cfg: ModelConfig, spec, batch: int, max_len: int,
                     dtype) -> Params:
    _, nh, hd = _mdims(cfg)
    return {
        "c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
    }


def _mlstm_chunked(q, k, v, ig, fg, c0, n0, chunk: int, eps: float = 1e-6):
    """q,k,v: (B,S,NH,HD); ig,fg: (B,S,NH) soft-capped pre-activations.
    Returns (y, c_final, n_final)."""
    bsz, s, nh, hd = q.shape
    qq = min(chunk, s)
    assert s % qq == 0
    nc = s // qq
    shp = (bsz, nc, qq, nh)
    qr = (q.reshape(*shp, hd) / (hd ** 0.5)).astype(jnp.float32)
    kr = k.reshape(*shp, hd).astype(jnp.float32)
    vr = v.reshape(*shp, hd).astype(jnp.float32)
    igr = ig.reshape(shp).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg.reshape(shp).astype(jnp.float32))
    fcum = jnp.cumsum(logf, axis=2)                      # (B,NC,Q,NH)
    ftot = fcum[:, :, -1]

    # intra-chunk: w[t,u] = q_t.k_u * exp(F_t - F_u + i_u), u <= t
    gap = fcum[:, :, :, None, :] - fcum[:, :, None, :, :] \
        + igr[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((qq, qq), bool))
    dmat = jnp.where(tri[None, None, :, :, None], jnp.exp(gap), 0.0)
    scores = jnp.einsum("bcqnh,bcunh->bcqun", qr, kr) * dmat
    y_num = jnp.einsum("bcqun,bcunh->bcqnh", scores, vr)
    y_den = jnp.sum(scores, axis=3)                      # (B,NC,Q,NH)

    # chunk state contributions
    decay_u = jnp.exp(ftot[:, :, None] - fcum + igr)     # (B,NC,Q,NH)
    dc = jnp.einsum("bcun,bcunh,bcund->bcnhd",
                    decay_u, kr, vr)                     # (B,NC,NH,HD,HD)
    dn = jnp.einsum("bcun,bcunh->bcnh", decay_u, kr)     # (B,NC,NH,HD)
    adec = jnp.exp(ftot)                                 # (B,NC,NH)

    def comb(lhs, rhs):
        (a1, c1, n1), (a2, c2, n2) = lhs, rhs
        return (a1 * a2,
                a2[..., None, None] * c1 + c2,
                a2[..., None] * n1 + n2)

    acc = jax.lax.associative_scan(comb, (adec, dc, dn), axis=1)
    prod_a = jnp.concatenate(
        [jnp.ones_like(acc[0][:, :1]), acc[0][:, :-1]], axis=1)
    c_in = prod_a[..., None, None] * c0[:, None] + jnp.concatenate(
        [jnp.zeros_like(acc[1][:, :1]), acc[1][:, :-1]], axis=1)
    n_in = prod_a[..., None] * n0[:, None] + jnp.concatenate(
        [jnp.zeros_like(acc[2][:, :1]), acc[2][:, :-1]], axis=1)

    w_in = jnp.exp(fcum)                                  # (B,NC,Q,NH)
    y_num = y_num + jnp.einsum("bcqnh,bcnhd,bcqn->bcqnd", qr, c_in, w_in)
    y_den = y_den + jnp.einsum("bcqnh,bcnh,bcqn->bcqn", qr, n_in, w_in)
    y = y_num / (jnp.maximum(jnp.abs(y_den), 1.0)[..., None] + eps)

    c_f = acc[0][:, -1][..., None, None] * c0 + acc[1][:, -1]
    n_f = acc[0][:, -1][..., None] * n0 + acc[2][:, -1]
    return y.reshape(bsz, s, nh, hd), c_f, n_f


def apply_mlstm(params: Params, cfg: ModelConfig, spec, x: jax.Array,
                cache: Params | None = None
                ) -> tuple[jax.Array, Params | None]:
    bsz, s, _ = x.shape
    d_inner, nh, hd = _mdims(cfg)
    up = jnp.einsum("bsd,dn->bsn", x, params["up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsn,nm->bsm", xi, params["wq"]).reshape(bsz, s, nh, hd)
    k = jnp.einsum("bsn,nm->bsm", xi, params["wk"]).reshape(bsz, s, nh, hd)
    v = jnp.einsum("bsn,nm->bsm", xi, params["wv"]).reshape(bsz, s, nh, hd)
    gates = jnp.einsum("bsn,nm->bsm", xi, params["w_gates"])
    ig, fg = jnp.split(soft_cap(gates, GATE_CAP), 2, axis=-1)  # (B,S,NH)

    if s == 1 and cache is not None:  # decode
        c0, n0 = cache["c"], cache["n"]
        logf = jax.nn.log_sigmoid(fg[:, 0].astype(jnp.float32))
        iexp = jnp.exp(ig[:, 0].astype(jnp.float32))
        fexp = jnp.exp(logf)
        kv = jnp.einsum("bnh,bnd->bnhd", k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32))
        c1 = fexp[..., None, None] * c0 + iexp[..., None, None] * kv
        n1 = fexp[..., None] * n0 + iexp[..., None] * k[:, 0].astype(
            jnp.float32)
        qf = q[:, 0].astype(jnp.float32) / (hd ** 0.5)
        num = jnp.einsum("bnh,bnhd->bnd", qf, c1)
        den = jnp.einsum("bnh,bnh->bn", qf, n1)
        y = (num / (jnp.maximum(jnp.abs(den), 1.0)[..., None] + 1e-6)
             ).reshape(bsz, 1, d_inner)
        new_cache = {"c": c1, "n": n1}
    else:
        c0 = (cache["c"] if cache is not None
              else jnp.zeros((bsz, nh, hd, hd), jnp.float32))
        n0 = (cache["n"] if cache is not None
              else jnp.zeros((bsz, nh, hd), jnp.float32))
        # pad to a chunk multiple with inert gates: i=-inf (no input),
        # f=+large (decay 1) so the carried state is untouched
        qq = min(cfg.xlstm.chunk, s)
        pad = (-s) % qq
        if pad:
            p3 = lambda arr, val: jnp.pad(
                arr, [(0, 0), (0, pad)] + [(0, 0)] * (arr.ndim - 2),
                constant_values=val)
            q, k, v = p3(q, 0), p3(k, 0), p3(v, 0)
            ig, fg = p3(ig, -30.0), p3(fg, 30.0)
        y, cf, nf = _mlstm_chunked(q, k, v, ig, fg, c0, n0, cfg.xlstm.chunk)
        y = y[:, :s].reshape(bsz, s, d_inner)
        new_cache = None if cache is None else {"c": cf, "n": nf}

    y = rmsnorm(params["norm"], y.astype(x.dtype), eps=cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsn,nd->bsd", y, params["down"]), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _sdims(cfg: ModelConfig):
    nh = cfg.num_heads
    return cfg.d_model, nh, cfg.d_model // nh


def init_slstm(key, cfg: ModelConfig, spec=None) -> Params:
    dt = cfg.compute_dtype
    d, nh, hd = _sdims(cfg)
    pf = cfg.xlstm.slstm_proj_factor
    d_up = int(d * pf)
    ks = take_keys(key, 4)
    return {
        "w_in": dense_init(ks[0], d, (4 * d,), dt),       # i,f,z,o pre-acts
        "r": (jax.random.normal(ks[1], (4, nh, hd, hd)) /
              (hd ** 0.5)).astype(dt),                    # block-diag recurrent
        "norm": rmsnorm_init(d, dt),
        "up_gate": dense_init(ks[2], d, (2 * d_up,), dt),
        "down": dense_init(ks[3], d_up, (d,), dt),
    }


def init_slstm_cache(cfg: ModelConfig, spec, batch: int, max_len: int,
                     dtype) -> Params:
    d, nh, hd = _sdims(cfg)
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, nh, hd), -1e30)}


def _slstm_scan(pre, r, state):
    """pre: (B,S,4,NH,HD) input pre-activations; r: (4,NH,HD,HD)."""
    def step(carry, p_t):
        h, c, n, m = carry
        rec = jnp.einsum("bnh,gnhk->bgnk", h, r)          # (B,4,NH,HD)
        zi, zf, zz, zo = [p_t[:, g] + rec[:, g] for g in range(4)]
        logf = jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(logf + m, zi)
        i = jnp.exp(zi - m_new)
        f = jnp.exp(logf + m - m_new)
        c = f * c + i * jnp.tanh(zz)
        n = f * n + i
        h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    pre_t = jnp.moveaxis(pre, 1, 0).astype(jnp.float32)   # (S,B,4,NH,HD)
    (h, c, n, m), ys = jax.lax.scan(step, state, pre_t)
    return jnp.moveaxis(ys, 0, 1), (h, c, n, m)           # (B,S,NH,HD)


def apply_slstm(params: Params, cfg: ModelConfig, spec, x: jax.Array,
                cache: Params | None = None
                ) -> tuple[jax.Array, Params | None]:
    bsz, s, d = x.shape
    _, nh, hd = _sdims(cfg)
    pre = jnp.einsum("bsd,dn->bsn", x, params["w_in"]).reshape(
        bsz, s, 4, nh, hd)
    state = (
        (cache["h"], cache["c"], cache["n"], cache["m"]) if cache is not None
        else tuple(jnp.zeros((bsz, nh, hd), jnp.float32) for _ in range(3))
        + (jnp.full((bsz, nh, hd), -1e30),))
    ys, (h, c, n, m) = _slstm_scan(pre, params["r"].astype(jnp.float32),
                                   state)
    new_cache = (None if cache is None
                 else {"h": h, "c": c, "n": n, "m": m})
    y = ys.reshape(bsz, s, d).astype(x.dtype)
    y = rmsnorm(params["norm"], y, eps=cfg.norm_eps)
    up = jnp.einsum("bsd,dn->bsn", y, params["up_gate"])
    a, b = jnp.split(up, 2, axis=-1)
    y = jax.nn.gelu(a, approximate=True) * b
    return jnp.einsum("bsn,nd->bsd", y, params["down"]), new_cache
