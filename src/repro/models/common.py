"""Shared model building blocks: norms, rotary embeddings, initializers.

Pure-JAX (no flax): parameters are nested dicts of jnp arrays; every layer is
an ``init(key, cfg) -> params`` plus an ``apply(params, x, ...) -> y`` pair.
Compute dtype policy: matmuls in ``cfg.dtype`` (bf16 by default), softmax /
norm statistics in fp32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of arrays


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_shape: Sequence[int], dtype) -> jax.Array:
    """Fan-in scaled normal init (matches common LM practice)."""
    scale = 1.0 / math.sqrt(max(in_dim, 1))
    return (jax.random.normal(key, (in_dim, *out_shape)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.zeros((dim,), dtype=dtype)}


def rmsnorm(params: Params, x: jax.Array, *, eps: float = 1e-6,
            zero_centered: bool = True) -> jax.Array:
    """RMSNorm with (1 + scale) parameterisation (gemma-style zero-centred)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    scale = (1.0 + scale) if zero_centered else scale
    return (xf * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0
               ) -> jax.Array:
    """Apply rotary embedding.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    Uses the "rotate half" convention (llama/gemma).
    """
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def soft_cap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None or cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def take_keys(key, n: int):
    return list(jax.random.split(key, n))


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """One assigned input-shape cell (seq_len x global_batch, kind)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPE_CASES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}
