"""Attention variants: GQA (full / sliding-window / soft-capped), DeepSeek
MLA, and gated cross-attention (VLM image layers).

Three execution modes share one code path:
  * train:   full sequence, causal mask, no cache.
  * prefill: full sequence, causal mask, writes the KV cache.
  * decode:  q_len == 1 against a pre-filled cache at ``pos``.

Caches are plain dicts of arrays so they stack cleanly under the
scan-over-layers used by :mod:`repro.models.model`.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common
from repro.parallel.annotate import hint
from repro.models.common import dense_init, rmsnorm, rmsnorm_init, take_keys
from repro.models.config import LayerSpec, ModelConfig

Params = Any


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    dt = cfg.compute_dtype
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = take_keys(key, 4)
    if cfg.fuse_qkv:
        p = {"wqkv": dense_init(k1, d, ((h + 2 * kv) * hd,), dt),
             "wo": dense_init(k4, h * hd, (d,), dt)}
    else:
        p = {
            "wq": dense_init(k1, d, (h * hd,), dt),
            "wk": dense_init(k2, d, (kv * hd,), dt),
            "wv": dense_init(k3, d, (kv * hd,), dt),
            "wo": dense_init(k4, h * hd, (d,), dt),
        }
    if spec.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def init_attn_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                    max_len: int, dtype) -> Params:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _attn_scale(cfg: ModelConfig) -> float:
    if cfg.attn_scale > 0:
        return cfg.attn_scale
    return 1.0 / math.sqrt(cfg.head_dim)


def apply_attn(params: Params, cfg: ModelConfig, spec: LayerSpec,
               x: jax.Array, positions: jax.Array,
               cache: Params | None = None) -> tuple[jax.Array, Params | None]:
    """x: (B, S, D); positions: (B, S) absolute positions.

    When ``cache`` is given and S > 1 this is prefill (cache written at
    [0, S)); when S == 1 it is a decode step at ``positions[:, 0]``.
    """
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.fuse_qkv:
        # one projection matmul + one FSDP gather instead of three
        wqkv = hint(params["wqkv"], "wt_d", "heads_out")
        qkv = jnp.einsum("bsd,dn->bsn", x, wqkv)
        q, k, v = jnp.split(qkv, [h * hd, (h + kv) * hd], axis=-1)
        q = q.reshape(b, s, h, hd)
        k = k.reshape(b, s, kv, hd)
        v = v.reshape(b, s, kv, hd)
    else:
        wq = hint(params["wq"], "wt_d", "heads_out")
        wk = hint(params["wk"], "wt_d", "kv_out")
        wv = hint(params["wv"], "wt_d", "kv_out")
        q = jnp.einsum("bsd,dn->bsn", x, wq).reshape(b, s, h, hd)
        k = jnp.einsum("bsd,dn->bsn", x, wk).reshape(b, s, kv, hd)
        v = jnp.einsum("bsd,dn->bsn", x, wv).reshape(b, s, kv, hd)
    q = hint(q, "batch", "attn_seq", "heads", None)
    k = hint(k, "batch", "seq", "kv_heads", None)
    v = hint(v, "batch", "seq", "kv_heads", None)
    if spec.qk_norm:
        q = rmsnorm(params["q_norm"], q, eps=cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, eps=cfg.norm_eps)
    q = common.apply_rope(q, positions, theta=cfg.rope_theta)
    k = common.apply_rope(k, positions, theta=cfg.rope_theta)

    scale = _attn_scale(cfg)
    softcap = cfg.attn_softcap or None
    window = spec.window or None

    if cache is None:
        out = ops.flash_attention(q, k, v, causal=True, window=window,
                                  softcap=softcap, scale=scale)
        out = hint(out, "batch", "attn_seq", "heads", None)
        return out.reshape(b, s, h * hd) @ hint(params["wo"], "heads_out", "wt_d"), None

    if s > 1:  # prefill
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"].astype(k.dtype), k, 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"].astype(v.dtype), v, 0, axis=1),
        }
        out = ops.flash_attention(q, k, v, causal=True, window=window,
                                  softcap=softcap, scale=scale)
        out = hint(out, "batch", "attn_seq", "heads", None)
        return out.reshape(b, s, h * hd) @ hint(params["wo"], "heads_out", "wt_d"), new_cache

    # decode: write (k, v) at pos then attend to the whole cache with a
    # validity mask (<= pos, > pos - window).
    pos = positions[:, 0]  # (B,)
    new_cache = {
        "k": _scatter_time(cache["k"], k[:, 0], pos),
        "v": _scatter_time(cache["v"], v[:, 0], pos),
    }
    out = ops.decode_attention(q, new_cache["k"], new_cache["v"],
                               lengths=pos + 1, window=window,
                               softcap=softcap, scale=scale)
    out = hint(out, "batch", "attn_seq", "heads", None)
    return out.reshape(b, s, h * hd) @ hint(params["wo"], "heads_out", "wt_d"), new_cache


def _scatter_time(buf: jax.Array, val: jax.Array, pos: jax.Array) -> jax.Array:
    """buf: (B, S, ...), val: (B, ...), pos: (B,) -> buf with val at pos."""
    b = buf.shape[0]
    return buf.astype(val.dtype).at[jnp.arange(b), pos].set(val)


# ---------------------------------------------------------------------------
# DeepSeek-V3 Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    dt = cfg.compute_dtype
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qd = m.nope_head_dim + m.rope_head_dim
    ks = take_keys(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, (m.q_lora_rank,), dt),
        "q_norm": rmsnorm_init(m.q_lora_rank, dt),
        "wq_b": dense_init(ks[1], m.q_lora_rank, (h * qd,), dt),
        "wkv_a": dense_init(ks[2], d, (m.kv_lora_rank + m.rope_head_dim,), dt),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dt),
        "wk_b": dense_init(ks[3], m.kv_lora_rank, (h * m.nope_head_dim,), dt),
        "wv_b": dense_init(ks[4], m.kv_lora_rank, (h * m.v_head_dim,), dt),
        "wo": dense_init(ks[5], h * m.v_head_dim, (d,), dt),
    }


def init_mla_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                   max_len: int, dtype) -> Params:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
    }


def _mla_attend_block(cfg: ModelConfig, q_nope, q_rope, ckv, krope,
                      wk_b, wv_b, mask, absorbed: bool) -> jax.Array:
    """One dense block of latent attention.

    q_nope: (B,S,H,dn)  q_rope: (B,S,H,dr)  ckv: (B,T,r)  krope: (B,T,dr)
    mask: broadcastable-to-(B,S,T) boolean (True = attend).

    ``absorbed``: beyond-paper optimization — fold wk_b/wv_b into the query /
    output side so the per-token work stays in latent space (no T x H x dn
    expansion).  Baseline expands K/V per head (DeepSeek's naive form).
    """
    m = cfg.mla
    h = cfg.num_heads
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    if absorbed:
        wk = wk_b.reshape(m.kv_lora_rank, h, m.nope_head_dim)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk)
        scores = jnp.einsum("bshr,btr->bhst", q_lat, ckv)
        scores = scores + jnp.einsum("bshr,btr->bhst", q_rope, krope)
        scores = scores.astype(jnp.float32) * scale
        scores = jnp.where(mask[:, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
        ctx = jnp.einsum("bhst,btr->bshr", p, ckv)  # latent context
        wv = wv_b.reshape(m.kv_lora_rank, h, m.v_head_dim)
        return jnp.einsum("bshr,rhv->bshv", ctx, wv)
    k_nope = jnp.einsum("btr,rn->btn", ckv, wk_b).reshape(
        *ckv.shape[:2], h, m.nope_head_dim)
    value = jnp.einsum("btr,rn->btn", ckv, wv_b).reshape(
        *ckv.shape[:2], h, m.v_head_dim)
    scores = jnp.einsum("bshn,bthn->bhst", q_nope, k_nope)
    scores = scores + jnp.einsum("bshr,btr->bhst", q_rope, krope)
    scores = scores.astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(value.dtype)
    return jnp.einsum("bhst,bthv->bshv", p, value)


_MLA_BLOCK_THRESHOLD = 8192
_MLA_Q_BLOCK = 1024


def _mla_attend_causal(cfg: ModelConfig, q_nope, q_rope, ckv, krope,
                       wk_b, wv_b, absorbed: bool) -> jax.Array:
    """Causal latent attention; blocks over queries past the threshold so
    the (S,T) score tensor never materialises at 32k+ (see kernels/ref.py
    BLOCK_THRESHOLD rationale)."""
    s, t = q_nope.shape[1], ckv.shape[1]
    if s <= _MLA_BLOCK_THRESHOLD:
        mask = (jnp.arange(s)[:, None] >= jnp.arange(t)[None, :])[None]
        return _mla_attend_block(cfg, q_nope, q_rope, ckv, krope, wk_b,
                                 wv_b, mask, absorbed)
    assert s % _MLA_Q_BLOCK == 0
    outs = []
    for i in range(s // _MLA_Q_BLOCK):
        qs = i * _MLA_Q_BLOCK
        hi = min(t, qs + _MLA_Q_BLOCK)
        mask = ((jnp.arange(_MLA_Q_BLOCK)[:, None] + qs)
                >= jnp.arange(hi)[None, :])[None]
        outs.append(_mla_attend_block(
            cfg, q_nope[:, qs:qs + _MLA_Q_BLOCK],
            q_rope[:, qs:qs + _MLA_Q_BLOCK],
            ckv[:, :hi], krope[:, :hi], wk_b, wv_b, mask, absorbed))
    return jnp.concatenate(outs, axis=1)


def apply_mla(params: Params, cfg: ModelConfig, spec: LayerSpec,
              x: jax.Array, positions: jax.Array,
              cache: Params | None = None, *,
              absorbed: bool = False) -> tuple[jax.Array, Params | None]:
    b, s, _ = x.shape
    m = cfg.mla
    h = cfg.num_heads
    q = jnp.einsum("bsd,dr->bsr", x, hint(params["wq_a"], "wt_d", None))
    q = rmsnorm(params["q_norm"], q, eps=cfg.norm_eps)
    q = jnp.einsum("bsr,rn->bsn", q,
                   hint(params["wq_b"], None, "heads_out")).reshape(
        b, s, h, m.nope_head_dim + m.rope_head_dim)
    q = hint(q, "batch", "attn_seq", "heads", None)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = common.apply_rope(q_rope, positions, theta=cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, hint(params["wkv_a"], "wt_d", None))
    ckv, krope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    ckv = rmsnorm(params["kv_norm"], ckv, eps=cfg.norm_eps)
    krope = common.apply_rope(krope[:, :, None], positions,
                              theta=cfg.rope_theta)[:, :, 0]

    if cache is None:
        out = _mla_attend_causal(cfg, q_nope, q_rope, ckv, krope,
                                 hint(params["wk_b"], None, "heads_out"), hint(params["wv_b"], None, "heads_out"), absorbed)
        return out.reshape(b, s, -1) @ hint(params["wo"], "heads_out", "wt_d"), None

    if s > 1:  # prefill
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"].astype(ckv.dtype), ckv, 0, axis=1),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                cache["krope"].astype(krope.dtype), krope, 0, axis=1),
        }
        out = _mla_attend_causal(cfg, q_nope, q_rope, ckv, krope,
                                 hint(params["wk_b"], None, "heads_out"), hint(params["wv_b"], None, "heads_out"), absorbed)
        return out.reshape(b, s, -1) @ hint(params["wo"], "heads_out", "wt_d"), new_cache

    pos = positions[:, 0]
    new_cache = {
        "ckv": _scatter_time(cache["ckv"], ckv[:, 0], pos),
        "krope": _scatter_time(cache["krope"], krope[:, 0], pos),
    }
    t = new_cache["ckv"].shape[1]
    mask = jnp.arange(t)[None, None, :] <= pos[:, None, None]  # (B,1,T)
    out = _mla_attend_block(cfg, q_nope, q_rope, new_cache["ckv"],
                            new_cache["krope"], params["wk_b"],
                            params["wv_b"], mask, absorbed)
    return out.reshape(b, s, -1) @ hint(params["wo"], "heads_out", "wt_d"), new_cache


# ---------------------------------------------------------------------------
# Gated cross-attention (VLM image layers; frontend is a stub per spec)
# ---------------------------------------------------------------------------

def init_cross_attn(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    dt = cfg.compute_dtype
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = take_keys(key, 5)
    return {
        "wq": dense_init(ks[0], d, (h * hd,), dt),
        "wk": dense_init(ks[1], cfg.vision_dim, (kv * hd,), dt),
        "wv": dense_init(ks[2], cfg.vision_dim, (kv * hd,), dt),
        "wo": dense_init(ks[3], h * hd, (d,), dt),
        "gate": jnp.zeros((), dt),
        "q_norm": rmsnorm_init(hd, dt),
        "k_norm": rmsnorm_init(hd, dt),
    }


def init_cross_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype) -> Params:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, cfg.num_image_tokens, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "filled": jnp.zeros((), jnp.int32)}


def apply_cross_attn(params: Params, cfg: ModelConfig, spec: LayerSpec,
                     x: jax.Array, image_embeds: jax.Array | None,
                     cache: Params | None = None
                     ) -> tuple[jax.Array, Params | None]:
    """x: (B,S,D); image_embeds: (B, N_img, vision_dim) or None in decode
    (then K/V come from the cache filled at prefill)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dn->bsn",
                   x, hint(params["wq"], "wt_d", "heads_out")
                   ).reshape(b, s, h, hd)
    q = hint(q, "batch", "attn_seq", "heads", None)
    q = rmsnorm(params["q_norm"], q, eps=cfg.norm_eps)

    if image_embeds is not None:
        k = jnp.einsum("bnd,dm->bnm", image_embeds,
                       hint(params["wk"], "wt_d", "kv_out")).reshape(
            b, -1, kv, hd)
        k = rmsnorm(params["k_norm"], k, eps=cfg.norm_eps)
        v = jnp.einsum("bnd,dm->bnm", image_embeds,
                       hint(params["wv"], "wt_d", "kv_out")).reshape(
            b, -1, kv, hd)
        new_cache = None
        if cache is not None:
            new_cache = {"k": k.astype(cache["k"].dtype),
                         "v": v.astype(cache["v"].dtype),
                         "filled": jnp.ones((), jnp.int32)}
    else:
        assert cache is not None, "decode cross-attn needs a filled cache"
        k, v = cache["k"], cache["v"]
        new_cache = cache

    out = ops.flash_attention(q, k, v, causal=False, window=None,
                              softcap=None, scale=1.0 / math.sqrt(hd))
    out = hint(out, "batch", "attn_seq", "heads", None)
    out = out.reshape(b, s, h * hd) @ hint(params["wo"], "heads_out", "wt_d")
    gate = jnp.tanh(params["gate"].astype(jnp.float32)).astype(out.dtype)
    return out * gate, new_cache
