from repro.models import model
from repro.models.config import (GroupSpec, LayerSpec, MambaConfig,
                                 MLAConfig, ModelConfig, MoEConfig,
                                 XLSTMConfig, uniform_groups)
from repro.models.model import (abstract_cache, abstract_params, decode_step,
                                forward, init_cache, init_params, prefill)
