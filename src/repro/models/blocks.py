"""Layer composition and the scan-over-layers group machinery.

One *layer* = (pre-norm -> mixer block -> residual) + optional
(pre-norm -> MLP/MoE -> residual), with gemma2-style post-norms when
``spec.post_norms``.  A *group* scans a repeating pattern of layers with
stacked parameters; weight-shared slots (zamba2's shared attention) are
closed over instead of scanned.  ``cfg.unroll`` switches the scan to a
Python loop — used by the dry-run cost-accounting variants (DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, mamba2, xlstm
from repro.models.common import rmsnorm, rmsnorm_init, take_keys
from repro.models.config import GroupSpec, LayerSpec, ModelConfig
from repro.models.mlp import apply_mlp, init_mlp
from repro.models.moe import apply_moe, init_moe

Params = Any

_MIXER_INIT = {
    "attn": attention.init_attn,
    "mla": attention.init_mla,
    "cross_attn": attention.init_cross_attn,
    "mamba2": mamba2.init_mamba2,
    "mlstm": xlstm.init_mlstm,
    "slstm": xlstm.init_slstm,
}

_CACHE_INIT = {
    "attn": attention.init_attn_cache,
    "mla": attention.init_mla_cache,
    "cross_attn": attention.init_cross_cache,
    "mamba2": mamba2.init_mamba_cache,
    "mlstm": xlstm.init_mlstm_cache,
    "slstm": xlstm.init_slstm_cache,
}

ZERO_AUX = {"moe_aux_loss": jnp.zeros((), jnp.float32),
            "moe_dropped": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    k1, k2 = take_keys(key, 2)
    dt = cfg.compute_dtype
    p: dict = {}
    if spec.kind != "none":
        p["pre_norm"] = rmsnorm_init(cfg.d_model, dt)
        p["mixer"] = _MIXER_INIT[spec.kind](k1, cfg, spec)
        if spec.post_norms:
            p["post_norm"] = rmsnorm_init(cfg.d_model, dt)
    if spec.mlp != "none":
        p["pre_mlp_norm"] = rmsnorm_init(cfg.d_model, dt)
        p["mlp"] = (init_moe(k2, cfg) if spec.mlp == "moe"
                    else init_mlp(k2, cfg))
        if spec.post_norms:
            p["post_mlp_norm"] = rmsnorm_init(cfg.d_model, dt)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype) -> Params:
    if spec.kind == "none":
        return {}
    return _CACHE_INIT[spec.kind](cfg, spec, batch, max_len, dtype)


def apply_layer(params: Params, cfg: ModelConfig, spec: LayerSpec,
                x: jax.Array, ctx: dict, cache: Params | None
                ) -> tuple[jax.Array, Params | None, dict]:
    aux = dict(ZERO_AUX)
    if spec.kind != "none":
        h = rmsnorm(params["pre_norm"], x, eps=cfg.norm_eps)
        if spec.kind == "attn":
            h, new_cache = attention.apply_attn(
                params["mixer"], cfg, spec, h, ctx["positions"], cache)
        elif spec.kind == "mla":
            h, new_cache = attention.apply_mla(
                params["mixer"], cfg, spec, h, ctx["positions"], cache,
                absorbed=ctx.get("mla_absorbed", False))
        elif spec.kind == "cross_attn":
            h, new_cache = attention.apply_cross_attn(
                params["mixer"], cfg, spec, h, ctx.get("image_embeds"), cache)
        elif spec.kind == "mamba2":
            h, new_cache = mamba2.apply_mamba2(params["mixer"], cfg, spec, h,
                                               cache)
        elif spec.kind == "mlstm":
            h, new_cache = xlstm.apply_mlstm(params["mixer"], cfg, spec, h,
                                             cache)
        elif spec.kind == "slstm":
            h, new_cache = xlstm.apply_slstm(params["mixer"], cfg, spec, h,
                                             cache)
        else:  # pragma: no cover
            raise ValueError(spec.kind)
        if spec.post_norms:
            h = rmsnorm(params["post_norm"], h, eps=cfg.norm_eps)
        x = x + h
    else:
        new_cache = cache

    if spec.mlp != "none":
        h = rmsnorm(params["pre_mlp_norm"], x, eps=cfg.norm_eps)
        if spec.mlp == "moe":
            h, moe_aux = apply_moe(params["mlp"], cfg, h)
            aux["moe_aux_loss"] = moe_aux["moe_aux_loss"].astype(jnp.float32)
            aux["moe_dropped"] = moe_aux["moe_dropped"].astype(jnp.float32)
        else:
            h = apply_mlp(params["mlp"], cfg, h)
        if spec.post_norms:
            h = rmsnorm(params["post_mlp_norm"], h, eps=cfg.norm_eps)
        x = x + h
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Groups (scan over repeats)
# ---------------------------------------------------------------------------

def init_group(key, cfg: ModelConfig, gspec: GroupSpec) -> Params:
    slot_params = []
    keys = take_keys(key, len(gspec.pattern))
    for spec, k in zip(gspec.pattern, keys):
        if spec.shared:
            slot_params.append(init_layer(k, cfg, spec))
        else:
            ks = jax.random.split(k, gspec.repeat)
            slot_params.append(
                jax.vmap(lambda kk: init_layer(kk, cfg, spec))(ks))
    return {"slots": tuple(slot_params)}


def init_group_cache(cfg: ModelConfig, gspec: GroupSpec, batch: int,
                     max_len: int, dtype) -> Params:
    slots = []
    for spec in gspec.pattern:
        one = init_layer_cache(cfg, spec, batch, max_len, dtype)
        slots.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (gspec.repeat, *a.shape)).copy()
            if hasattr(a, "shape") else a, one))
    return {"slots": tuple(slots)}


def apply_group(params: Params, cfg: ModelConfig, gspec: GroupSpec,
                x: jax.Array, ctx: dict, cache: Params | None
                ) -> tuple[jax.Array, Params | None, dict]:
    pattern = gspec.pattern
    scanned_params = tuple(p for spec, p in zip(pattern, params["slots"])
                           if not spec.shared)
    shared_params = tuple(p for spec, p in zip(pattern, params["slots"])
                          if spec.shared)

    def body(carry, per_repeat):
        xc, aux_acc = carry
        sl_params, sl_caches = per_repeat
        it_sc, it_sh = iter(sl_params), iter(shared_params)
        new_caches = []
        for i, spec in enumerate(pattern):
            p = next(it_sh) if spec.shared else next(it_sc)
            c = sl_caches[i] if (sl_caches is not None and sl_caches[i]) \
                else None
            xc, nc, aux = apply_layer(p, cfg, spec, xc, ctx, c)
            new_caches.append({} if nc is None else nc)
            aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
        return (xc, aux_acc), tuple(new_caches)

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.checkpoint_dots
                  if cfg.remat == "dots" else None)
        body = jax.checkpoint(body, policy=policy, prevent_cse=cfg.unroll)

    aux0 = dict(ZERO_AUX)
    sl_caches = None if cache is None else cache["slots"]
    if cfg.unroll:
        carry = (x, aux0)
        new_slots = [[] for _ in pattern]
        for r in range(gspec.repeat):
            sp = tuple(jax.tree.map(lambda a: a[r], p) for p in scanned_params)
            sc = (None if sl_caches is None else
                  tuple(jax.tree.map(lambda a: a[r], c) for c in sl_caches))
            carry, ncs = body(carry, (sp, sc))
            for i, nc in enumerate(ncs):
                new_slots[i].append(nc)
        (x, aux) = carry
        if cache is None:
            return x, None, aux
        stacked = tuple(
            jax.tree.map(lambda *a: jnp.stack(a), *ns) if ns and ns[0] else {}
            for ns in new_slots)
        return x, {"slots": stacked}, aux

    xs = (scanned_params,
          sl_caches if sl_caches is not None
          else tuple(None for _ in pattern))
    if sl_caches is None:
        # scan requires uniform xs; use empty dicts as per-slot cache stand-in
        xs = (scanned_params, tuple({} for _ in pattern))
    (x, aux), new_caches = jax.lax.scan(body, (x, aux0), xs)
    if cache is None:
        return x, None, aux
    return x, {"slots": new_caches}, aux
