"""Gated-linear-unit MLPs (SwiGLU / GeGLU)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS, dense_init, take_keys
from repro.models.config import ModelConfig
from repro.parallel.annotate import hint

Params = Any


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None,
             gated: bool | None = None) -> Params:
    dt = cfg.compute_dtype
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    gated = cfg.gated_mlp if gated is None else gated
    k1, k2, k3 = take_keys(key, 3)
    if gated and cfg.fuse_glu:
        # (D, 2, F) layout: F stays contiguous per shard after the split
        return {"wgu": dense_init(k1, d, (2, f), dt),
                "wo": dense_init(k3, f, (d,), dt)}
    p = {
        "wi": dense_init(k1, d, (f,), dt),   # gate (or sole up) proj
        "wo": dense_init(k3, f, (d,), dt),   # down proj
    }
    if gated:
        p["wu"] = dense_init(k2, d, (f,), dt)  # up proj
    return p


def apply_mlp(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = ACTIVATIONS[cfg.activation]
    # weight hints = just-in-time FSDP gather (strip any 'data' shard) +
    # keep the TP dim explicit so GSPMD never replicates the F dim
    wo = hint(params["wo"], "ffn", "wt_d")
    if "wgu" in params:  # fused gate+up: one matmul, one gather
        wgu = hint(params["wgu"], "wt_d", None, "ffn")
        gu = jnp.einsum("bsd,dgf->bsgf", x, wgu)
        h = act(gu[:, :, 0]) * gu[:, :, 1]
    else:
        wi = hint(params["wi"], "wt_d", "ffn")
        h = act(jnp.einsum("bsd,df->bsf", x, wi))
        if "wu" in params:
            h = h * jnp.einsum("bsd,df->bsf", x,
                               hint(params["wu"], "wt_d", "ffn"))
    h = hint(h, "batch", "seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, wo)
