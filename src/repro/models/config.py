"""Model configuration dataclasses.

A model is a stack of *groups*; each group is a repeating *pattern* of layer
specs, scanned over the repeat axis (`lax.scan` with stacked params).  This
uniformly expresses dense stacks (pattern of 1), gemma2's local/global
alternation (pattern of 2), zamba2's Mamba-with-shared-attention hybrid
(pattern of 6 with a weight-shared slot), xLSTM's mLSTM/sLSTM mix and the
VLM's periodic cross-attention layers.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_expert: int = 0                 # expert hidden dim (0 -> use d_ff)
    num_shared: int = 0               # dense "shared" experts (DeepSeek-MoE)
    capacity_factor: float = 1.25
    group_size: int = 2048            # dispatch-group tokens (see moe.py)
    router: str = "softmax"           # 'softmax' | 'sigmoid' (DeepSeek-V3)
    router_bias: bool = False         # aux-loss-free bias update (DSv3)
    aux_loss_weight: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                # mamba2 SSD head dim
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0          # mLSTM up-projection
    slstm_proj_factor: float = 1.3334
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One slot in a group pattern.

    kind: 'attn' | 'mla' | 'mamba2' | 'mlstm' | 'slstm' | 'cross_attn'
          | 'none' (pure-MLP layer)
    mlp:  'glu' | 'moe' | 'none'
    """
    kind: str = "attn"
    mlp: str = "glu"
    window: int = 0                   # >0 -> sliding-window attention
    shared: bool = False              # weight-shared across group repeats
    post_norms: bool = False          # gemma2-style post-block RMSNorm
    qk_norm: bool = False


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    pattern: tuple[LayerSpec, ...]
    repeat: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    groups: tuple[GroupSpec, ...]
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention extras
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    attn_scale: float = 0.0           # 0 -> 1/sqrt(head_dim)
    # sub-configs
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    xlstm: XLSTMConfig | None = None
    # embedding / head
    tie_embeddings: bool = True
    scale_embed: bool = False         # gemma multiplies embeds by sqrt(d)
    num_codebooks: int = 0            # musicgen: parallel codebook streams
    # modality frontend stubs
    vision_dim: int = 0               # >0 -> expects precomputed image embeds
    num_image_tokens: int = 0
    # numerics / training
    activation: str = "silu"
    gated_mlp: bool = True            # GLU (False -> plain 2-matrix MLP)
    unroll: bool = False              # Python-loop layers (dry-run costing)
    # beyond-paper perf knobs (EXPERIMENTS.md §Perf)
    fuse_qkv: bool = False            # single QKV projection matmul
    fuse_glu: bool = False            # single gate+up projection matmul
    seq_parallel: bool = False        # shard residual-stream seq over TP
    loss_dtype: str = "float32"       # logsumexp accumulation dtype
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "full"               # 'none' | 'full' | 'dots'
    # distribution policy (consumed by repro.parallel.sharding)
    fsdp: bool = False                # shard big weight dims over 'data' too
    moe_sharding: str = "auto"        # 'auto' | 'ep2d' | 'ep_fsdp' | 'tp'
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False
    # optimizer choice for train_step lowering
    optimizer: str = "adamw"          # 'adamw' | 'adafactor' | 'lion'

    @property
    def num_layers(self) -> int:
        return sum(len(g.pattern) * g.repeat for g in self.groups)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        import numpy as np
        from repro.models import model as model_lib
        shapes = model_lib.abstract_params(self)
        import jax
        return int(sum(np.prod(x.shape) for x in jax.tree.leaves(shapes)))

    def active_param_count(self) -> int:
        """Params active per token (MoE counts top_k+shared experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        import numpy as np
        import jax
        from repro.models import model as model_lib
        shapes = model_lib.abstract_params(self)
        flat = jax.tree.flatten_with_path(shapes)[0]
        inactive = 0
        for path, leaf in flat:
            keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
            if "experts" in keys:
                frac = 1.0 - (self.moe.top_k / self.moe.num_experts)
                inactive += int(np.prod(leaf.shape) * frac)
        return total - inactive


def uniform_groups(n_layers: int, spec: LayerSpec) -> tuple[GroupSpec, ...]:
    return (GroupSpec(pattern=(spec,), repeat=n_layers),)
