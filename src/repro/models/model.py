"""Top-level LM: embeddings -> layer groups -> final norm -> head(s).

Exposes the three execution paths the shape cells exercise:
  * ``forward``      - training forward (full sequence, no cache)
  * ``prefill``      - fill caches for a prompt, return last-token logits
  * ``decode_step``  - one token against the cache

MusicGen-style multi-codebook streams (tokens (B,S,K)) and VLM image-embed
stubs (``image_embeds`` forwarded to cross-attention layers) are handled
here so every assigned arch shares one code path.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.common import soft_cap, take_keys, rmsnorm, rmsnorm_init
from repro.models.common import embed_init, dense_init
from repro.models.config import ModelConfig
from repro.parallel.annotate import hint

Params = Any


def init_params(key, cfg: ModelConfig) -> Params:
    dt = cfg.compute_dtype
    keys = take_keys(key, len(cfg.groups) + 2)
    if cfg.num_codebooks:
        ek = jax.random.split(keys[0], cfg.num_codebooks)
        embed = jax.vmap(
            lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dt))(ek)
    else:
        embed = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)
    p = {
        "embed": embed,
        "groups": [blocks.init_group(k, cfg, g)
                   for g, k in zip(cfg.groups, keys[1:-1])],
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            hk = jax.random.split(keys[-1], cfg.num_codebooks)
            p["head"] = jax.vmap(
                lambda k: dense_init(k, cfg.d_model, (cfg.vocab_size,), dt)
            )(hk)
        else:
            p["head"] = dense_init(keys[-1], cfg.d_model, (cfg.vocab_size,),
                                   dt)
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(init_params, cfg=cfg), key)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Params:
    dtype = dtype or cfg.compute_dtype
    return [blocks.init_group_cache(cfg, g, batch, max_len, dtype)
            for g in cfg.groups]


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len, dtype))


def _embed(params: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    if cfg.num_codebooks:
        # tokens: (B, S, K) -> sum of per-codebook embeddings
        embs = jax.vmap(lambda e, t: jnp.take(e, t, axis=0))(
            params["embed"], jnp.moveaxis(tokens, -1, 0))     # (K,B,S,D)
        x = jnp.sum(embs, axis=0)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return hint(x, "batch", "seq", "embed")


def _head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.num_codebooks:
        w = params.get("head", params["embed"])  # (K,V,D) if tied
        if "head" in params:
            logits = jnp.einsum("bsd,kdv->bskv", x, w)
        else:
            logits = jnp.einsum("bsd,kvd->bskv", x, w)
    else:
        if "head" in params:
            logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
        else:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    axes = (("batch", "seq", None, "vocab") if cfg.num_codebooks
            else ("batch", "seq", "vocab"))
    logits = hint(logits, *axes)
    return soft_cap(logits, cfg.final_softcap or None)


def _run(params: Params, cfg: ModelConfig, x: jax.Array, ctx: dict,
         caches: list | None):
    aux = dict(blocks.ZERO_AUX)
    new_caches = [] if caches is not None else None
    for gi, gspec in enumerate(cfg.groups):
        c = None if caches is None else caches[gi]
        x, nc, ga = blocks.apply_group(params["groups"][gi], cfg, gspec, x,
                                       ctx, c)
        if new_caches is not None:
            new_caches.append(nc)
        aux = {k: aux[k] + ga[k] for k in aux}
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    return x, new_caches, aux


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            image_embeds: jax.Array | None = None
            ) -> tuple[jax.Array, dict]:
    """Training forward. tokens: (B,S) or (B,S,K). Returns (logits, aux)."""
    b, s = tokens.shape[:2]
    x = _embed(params, cfg, tokens)
    ctx = {"positions": jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
           "image_embeds": image_embeds}
    x, _, aux = _run(params, cfg, x, ctx, None)
    return _head(params, cfg, x), aux


def forward_loss(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 labels: jax.Array, image_embeds: jax.Array | None = None
                 ) -> tuple[jax.Array, dict]:
    """Training forward + token cross-entropy, sharding-friendly.

    The gold logit is computed by gathering the label's head row and dotting
    with the hidden state — O(B*S*D) — instead of take_along_axis over the
    vocab-sharded (B,S,V) logits (which would force GSPMD to replicate
    them).  Only the logsumexp reduction touches the full logits tensor.
    """
    b, s = tokens.shape[:2]
    x = _embed(params, cfg, tokens)
    ctx = {"positions": jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
           "image_embeds": image_embeds}
    x, _, aux = _run(params, cfg, x, ctx, None)

    logits = _head(params, cfg, x)  # (B,S,V) or (B,S,K,V)
    lse = jax.nn.logsumexp(logits.astype(jnp.dtype(cfg.loss_dtype)),
                           axis=-1).astype(jnp.float32)

    if cfg.num_codebooks:
        w = params.get("head")
        wv = (jnp.swapaxes(w, 1, 2) if w is not None
              else params["embed"])                        # (K,V,D)
        rows = jax.vmap(lambda e, t: jnp.take(e, t, axis=0),
                        in_axes=(0, 2))(wv, labels)        # (K,B,S,D)
        gold = jnp.einsum("bsd,kbsd->bsk", x.astype(jnp.float32),
                          rows.astype(jnp.float32))
    else:
        w = params.get("head")
        wv = jnp.swapaxes(w, 0, 1) if w is not None else params["embed"]
        rows = jnp.take(wv, labels, axis=0)                # (B,S,D)
        gold = jnp.sum(x.astype(jnp.float32)
                       * rows.astype(jnp.float32), axis=-1)
    if cfg.final_softcap:
        gold = soft_cap(gold, cfg.final_softcap)
    loss = jnp.mean(lse - gold)
    return loss, aux


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            cache: list, image_embeds: jax.Array | None = None,
            mla_absorbed: bool = False) -> tuple[jax.Array, list]:
    """Fill the cache with a prompt; returns (last-token logits, cache)."""
    b, s = tokens.shape[:2]
    x = _embed(params, cfg, tokens)
    ctx = {"positions": jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
           "image_embeds": image_embeds, "mla_absorbed": mla_absorbed}
    x, new_caches, _ = _run(params, cfg, x, ctx, cache)
    return _head(params, cfg, x[:, -1:]), new_caches


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: list, pos: jax.Array,
                mla_absorbed: bool = False) -> tuple[jax.Array, list]:
    """tokens: (B,1) or (B,1,K); pos: (B,) absolute position of the token."""
    b = tokens.shape[0]
    x = _embed(params, cfg, tokens)
    ctx = {"positions": pos[:, None], "image_embeds": None,
           "mla_absorbed": mla_absorbed}
    x, new_caches, _ = _run(params, cfg, x, ctx, cache)
    return _head(params, cfg, x), new_caches
